"""Vectorised label-set kernels vs the scalar reference, and incremental
re-plan exactness.

The kernels in ``repro.core.lattice.labelset`` are the hot inner loops of
every lattice DP, so their keep semantics are pinned label-for-label
against :func:`nondominated_rows_scalar` — the unvectorised
specification — over randomized arrays with duplicates, ties, the ε > 0
archive path, and sizes past the pairwise/sweep crossover.  A seeded
sweep always runs; hypothesis (when installed) amplifies it.

The second half pins :meth:`QueryEngine.frontier_incremental`: warm
re-plans (resume after a resource loss, extend after a join, replay at
unchanged membership) must return exactly the cold frontier.
"""

import numpy as np
import pytest

from repro.core import Query, QueryEngine, objective_vector
from repro.core.lattice.labelset import (_PAIRWISE_MAX, grouped_nondominated,
                                         grouped_topk, nondominated_rows,
                                         nondominated_rows_scalar)
import repro.core.query as query_mod

from test_frontier_exact import _grid_space

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # degrade to the deterministic sweeps only
    HAVE_HYPOTHESIS = False

_vec = objective_vector


def _random_labels(rng, n=None, m=None, grid=8):
    """Label arrays drawn from a coarse dyadic grid so exact duplicates
    and per-column ties are common — the cases where dominance pruning
    semantics (first-occurrence collapse, lexicographic ε archive) can
    silently diverge between implementations."""
    n = int(rng.integers(0, 40)) if n is None else n
    m = int(rng.integers(2, 6)) if m is None else m
    return rng.integers(0, grid, size=(n, m)).astype(np.float64) / grid


class TestNondominatedRows:
    """nondominated_rows == nondominated_rows_scalar, index for index."""

    def test_seeded_sweep_exact(self):
        for seed in range(200):
            rng = np.random.default_rng(seed)
            pts = _random_labels(rng)
            got = nondominated_rows(pts)
            want = nondominated_rows_scalar(pts)
            assert np.array_equal(got, want), (seed, pts)

    def test_seeded_sweep_epsilon(self):
        for seed in range(200):
            rng = np.random.default_rng(1000 + seed)
            pts = _random_labels(rng) + 1.0 / 16   # ε is multiplicative
            eps = float(rng.choice([0.05, 0.25, 1.0]))
            got = nondominated_rows(pts, eps)
            want = nondominated_rows_scalar(pts, eps)
            assert np.array_equal(got, want), (seed, eps, pts)

    def test_past_pairwise_crossover(self):
        # > _PAIRWISE_MAX unique rows exercises the chunked sweep path
        for seed, eps in ((0, 0.0), (1, 0.0), (2, 0.1)):
            rng = np.random.default_rng(seed)
            pts = _random_labels(rng, n=_PAIRWISE_MAX + 300, m=3,
                                 grid=64) + 1.0 / 64
            assert len(np.unique(pts, axis=0)) > _PAIRWISE_MAX
            assert np.array_equal(nondominated_rows(pts, eps),
                                  nondominated_rows_scalar(pts, eps))

    def test_degenerate_shapes(self):
        empty = np.empty((0, 3))
        assert np.array_equal(nondominated_rows(empty), np.arange(0))
        one = np.array([[1.0, 2.0]])
        assert np.array_equal(nondominated_rows(one), [0])
        dup = np.array([[1.0, 2.0], [1.0, 2.0]])
        assert np.array_equal(nondominated_rows(dup), [0])

    if HAVE_HYPOTHESIS:
        @settings(max_examples=300, deadline=None)
        @given(st.integers(0, 2 ** 32 - 1), st.floats(0.0, 2.0))
        def test_hypothesis_amplifier(self, seed, eps):
            rng = np.random.default_rng(seed)
            pts = _random_labels(rng) + 1.0 / 16
            assert np.array_equal(nondominated_rows(pts, eps),
                                  nondominated_rows_scalar(pts, eps))


class TestGroupedKernels:
    """Fused grouped kernels == one scalar-reference call per group."""

    @staticmethod
    def _grouped_scalar(pts, keys, eps):
        out = []
        for k in np.unique(keys):
            idx = np.flatnonzero(keys == k)
            out.append(idx[nondominated_rows_scalar(pts[idx], eps)])
        return np.sort(np.concatenate(out)) if out else np.arange(0)

    def test_grouped_nondominated_sweep(self):
        for seed in range(150):
            rng = np.random.default_rng(seed)
            pts = _random_labels(rng) + 1.0 / 16
            keys = rng.integers(0, 4, size=len(pts))
            eps = float(rng.choice([0.0, 0.0, 0.1]))  # mostly fused path
            got = grouped_nondominated(pts, keys, eps)
            want = self._grouped_scalar(pts, keys, eps)
            assert np.array_equal(got, want), (seed, eps)

    def test_grouped_key_embedding_past_crossover(self):
        # ε == 0 with many rows takes the (key, -key) embedding through
        # nondominated_rows' sweep path; groups must still be watertight
        rng = np.random.default_rng(7)
        pts = _random_labels(rng, n=_PAIRWISE_MAX + 200, m=3, grid=64)
        keys = rng.integers(0, 6, size=len(pts))
        assert np.array_equal(grouped_nondominated(pts, keys, 0.0),
                              self._grouped_scalar(pts, keys, 0.0))

    def test_grouped_topk_sweep(self):
        for seed in range(150):
            rng = np.random.default_rng(seed)
            n = int(rng.integers(0, 50))
            keys = rng.integers(0, 5, size=n)
            scores = rng.integers(0, 6, size=n).astype(np.float64)
            k = int(rng.integers(1, 5))
            want = []
            for g in np.unique(keys):
                idx = np.flatnonzero(keys == g)
                # stable: ties on the score keep the earliest rows
                want.extend(idx[np.argsort(scores[idx], kind="stable")][:k])
            assert np.array_equal(grouped_topk(keys, scores, k),
                                  np.sort(np.asarray(want, dtype=np.intp)))


def _keyset(res):
    return {(c.segments, c.batch_size, c.replicas) for c in res.configs}


def _engine(n_cloud=2):
    return _grid_space(n_blocks=6, n_edge=2, n_cloud=n_cloud,
                       batches=(1, 2))


def _without(eng, name):
    res = [r for r in eng.resources if r.name != name]
    return QueryEngine(eng.db, res, eng.network, source=eng.source,
                       input_bytes=eng.input_bytes)


class TestFrontierIncremental:
    """Warm re-plans return exactly the cold frontier."""

    def test_steady_state_replay(self):
        eng = _engine()
        q = Query()
        cold, states = eng.frontier_incremental(q)
        assert states                      # one LabelState per swept batch
        warm, states2 = eng.frontier_incremental(q, states)
        assert _keyset(warm) == _keyset(cold)
        assert warm.strategy == "lattice"
        assert set(states2) == set(states)

    def test_resume_after_resource_loss(self):
        eng = _engine()
        _, states = eng.frontier_incremental(Query())
        eng2 = _without(eng, "cloud1")
        cold, _ = eng2.frontier_incremental(Query())
        warm, _ = eng2.frontier_incremental(Query(), states)
        assert _keyset(warm) == _keyset(cold)

    def test_resume_after_barred_resource_loss(self):
        # the high-reuse case: the departed resource was barred from early
        # blocks by a link budget, so most of the label prefix replays
        ob = np.asarray(_engine().cost.out_bytes, dtype=float)
        lim = float(np.sort(ob)[1])
        eng = _engine()
        others = [r.name for r in eng.resources if r.name != "cloud1"]
        q = Query(max_link_bytes={(o, "cloud1"): lim for o in others})
        _, states = eng.frontier_incremental(q)
        eng2 = _without(eng, "cloud1")
        cold, _ = eng2.frontier_incremental(q)
        warm, _ = eng2.frontier_incremental(q, states)
        assert _keyset(warm) == _keyset(cold)

    def test_extend_after_resource_join(self):
        full = _engine(n_cloud=2)          # cloud1 is last in the axis
        small = _without(full, "cloud1")
        _, states = small.frontier_incremental(Query())
        cold, _ = full.frontier_incremental(Query())
        warm, _ = full.frontier_incremental(Query(), states)
        assert _keyset(warm) == _keyset(cold)

    def test_constrained_replay_exact(self):
        eng = _engine()
        q = Query(must_use=("edge0",),
                  max_resource_time={"device0": 1.0})
        cold, states = eng.frontier_incremental(q)
        warm, _ = eng.frontier_incremental(q, states)
        assert _keyset(warm) == _keyset(cold)
        exh = eng.frontier(q, strategy="exhaustive")
        assert {_vec(c) for c in warm.configs} == \
               {_vec(c) for c in exh.configs}


class TestSolveTelemetry:
    """run()/frontier() expose pure solve time and label statistics."""

    def test_lattice_run_populates_labels(self):
        eng = _engine()
        old = query_mod.EXHAUSTIVE_LIMIT
        try:
            query_mod.EXHAUSTIVE_LIMIT = -1
            res = eng.run(Query(top_n=1))
        finally:
            query_mod.EXHAUSTIVE_LIMIT = old
        assert res.strategy == "lattice"
        assert res.labels_kept > 0
        assert 0.0 < res.solve_seconds <= res.query_time_s

    def test_frontier_populates_labels(self):
        eng = _engine()
        res = eng.frontier(strategy="lattice")
        assert res.labels_kept > 0
        assert 0.0 < res.solve_seconds <= res.query_time_s
        exh = eng.frontier(strategy="exhaustive")
        assert exh.solve_seconds > 0.0


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
