"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (AnalyticProvider, BottleneckLattice, Constraints,
                        CostModel, LATENCY, THROUGHPUT, Link, NetworkModel,
                        PartitionLattice, Resource, Segment, benchmark_model,
                        dominates, enumerate_partitions, linear_graph,
                        pareto_frontier, rank)
from repro.core.graph import LayerGraph, LayerNode
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.models.ssm import ssd
from repro.kernels.ref import ssd_ref

# ---------------------------------------------------------------------------
# graph invariants
# ---------------------------------------------------------------------------


@st.composite
def random_dag(draw):
    """Random single-source single-sink layer DAG."""
    n = draw(st.integers(3, 12))
    g = LayerGraph("rand")
    g.input(jax.ShapeDtypeStruct((1, 4), jnp.float32))
    for i in range(1, n):
        max_preds = min(i, 3)
        k = draw(st.integers(1, max_preds))
        preds = sorted(draw(st.sets(st.integers(0, i - 1), min_size=k,
                                    max_size=k)))
        g.add(LayerNode(f"n{i}", "add",
                        apply=lambda *xs: sum(xs) * 0.5), preds)
    # force single sink: connect all current sinks to a final node
    succs = g.succs
    sinks = [i for i, s in enumerate(succs) if not s]
    if len(sinks) > 1:
        g.add(LayerNode("sink", "add", apply=lambda *xs: sum(xs)), sinks)
    g.trace()
    return g


@given(random_dag())
@settings(max_examples=40, deadline=None)
def test_blocks_partition_the_graph(g):
    from repro.core import fuse_blocks
    blocks = fuse_blocks(g)
    ids = [i for b in blocks for i in b.node_ids]
    assert ids == list(range(g.n_layers))


@given(random_dag())
@settings(max_examples=40, deadline=None)
def test_block_chain_equals_graph(g):
    """Executing the fused block chain == executing the raw DAG."""
    from repro.core import fuse_blocks
    x = jnp.ones((1, 4))
    vals = [x]
    for i in range(1, g.n_layers):
        vals.append(g.nodes[i].apply(*[vals[p] for p in g.preds[i]]))
    want = vals[-1]
    y = x
    for b in fuse_blocks(g):
        y = b.make_callable()(y)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# benchmark-profile invariants
# ---------------------------------------------------------------------------

@st.composite
def monotone_profile(draw):
    """A measured batch profile whose per-batch time is monotone
    non-decreasing in batch size (larger batches never finish faster)."""
    batches = sorted(draw(st.sets(st.integers(1, 512), min_size=2,
                                  max_size=6)))
    deltas = draw(st.lists(st.floats(0.0, 1.0), min_size=len(batches),
                           max_size=len(batches)))
    t = draw(st.floats(1e-6, 1e-2))
    profile = {}
    for b, d in zip(batches, deltas):
        t += d * 1e-3
        profile[b] = (t, b * 1000)
    return profile


@given(monotone_profile(), st.integers(1, 1024), st.integers(1, 1024))
@settings(max_examples=60, deadline=None)
def test_interpolated_times_monotone_in_batch(profile, b1, b2):
    """Log-linear interpolation preserves monotonicity of the measured
    profile (and clamps outside the measured range)."""
    from repro.core.bench import _interp_profile
    lo, hi = min(b1, b2), max(b1, b2)
    t_lo = _interp_profile(profile, lo)
    t_hi = _interp_profile(profile, hi)
    assert t_lo <= t_hi + 1e-12
    bs = sorted(profile)
    assert _interp_profile(profile, bs[-1] + 100) == \
        pytest.approx(profile[bs[-1]][0])
    values = [profile[b][0] for b in bs]
    assert min(values) - 1e-12 <= t_lo <= max(values) + 1e-12


# ---------------------------------------------------------------------------
# partitioning invariants
# ---------------------------------------------------------------------------

def _toy_cost(n_blocks: int, seed: int) -> CostModel:
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(n_blocks):
        d = int(rng.integers(4, 16)) * 2
        layers.append(LayerNode(f"l{i}", "dense",
                                apply=lambda x, d=d: jnp.tile(
                                    x[..., :1], (1, d)),
                                flops=float(rng.integers(1, 100)) * 1e6))
    g = linear_graph(f"toy{seed}", jax.ShapeDtypeStruct((1, 8), jnp.float32),
                     layers)
    res = [Resource("device", "device", RPI4, speed_factor=30.0),
           Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.0),
           Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0)]
    db = benchmark_model(g, res, AnalyticProvider(), runs=1)
    net = NetworkModel(default=Link("l", 0.01, 1e6))
    return CostModel(db=db, resources=res, network=net, source="device",
                     input_bytes=1e5)


@given(st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_lattice_matches_oracle(seed):
    """DP lattice optimum == exhaustive optimum on random cost models."""
    cost = _toy_cost(6, seed)
    oracle = rank(enumerate_partitions(cost), LATENCY)[0]
    got = PartitionLattice(cost).solve(top_n=1)[0]
    assert abs(got.latency_s - oracle.latency_s) < 1e-9


@given(st.integers(0, 1000), st.integers(1, 5))
@settings(max_examples=15, deadline=None)
def test_topn_sorted_and_unique(seed, n):
    cost = _toy_cost(5, seed)
    configs = PartitionLattice(cost).solve(top_n=n)
    lats = [c.latency_s for c in configs]
    assert lats == sorted(lats)
    assert len({c.segments for c in configs}) == len(configs)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_constraints_never_improve_latency(seed):
    """Any constraint can only worsen (or keep) the optimum — a fundamental
    sanity property of constrained optimisation."""
    cost = _toy_cost(6, seed)
    free = PartitionLattice(cost).solve(top_n=1)[0]
    cons = Constraints(must_use=("device", "cloud"))
    constrained = PartitionLattice(cost, cons).solve(top_n=1)
    if constrained:
        assert constrained[0].latency_s >= free.latency_s - 1e-12


@given(st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_bottleneck_dp_matches_oracle(seed):
    """Min-bottleneck DP optimum == exhaustive throughput optimum."""
    cost = _toy_cost(6, seed)
    oracle = rank(enumerate_partitions(cost), THROUGHPUT)[0]
    got = BottleneckLattice(cost).solve(top_n=1)[0]
    assert abs(got.bottleneck_s - oracle.bottleneck_s) < 1e-9


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_pareto_frontier_sound_and_complete(seed):
    """No frontier member is dominated by any enumerated config, and every
    non-member is dominated by some frontier member."""
    cost = _toy_cost(5, seed)
    configs = enumerate_partitions(cost)
    front = pareto_frontier(configs)
    fset = {f.segments for f in front}
    for c in configs:
        if c.segments in fset:
            assert not any(dominates(o, c) for o in configs)
        else:
            assert any(dominates(f, c) for f in front)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_throughput_at_least_inverse_latency(seed):
    """Pipelining can only help: rate >= 1/latency for every config."""
    cost = _toy_cost(5, seed)
    for cfg in enumerate_partitions(cost):
        assert cfg.throughput_rps >= 1.0 / cfg.latency_s - 1e-12


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_faster_network_never_hurts(seed):
    """Monotonicity: infinitely fast links can only reduce the optimum."""
    cost_slow = _toy_cost(5, seed)
    fast_net = NetworkModel(default=Link("fast", 0.0, 1e12))
    cost_fast = CostModel(db=cost_slow.db, resources=cost_slow.resources,
                          network=fast_net, source="device",
                          input_bytes=1e5)
    slow = PartitionLattice(cost_slow).solve(top_n=1)[0]
    fast = PartitionLattice(cost_fast).solve(top_n=1)[0]
    assert fast.latency_s <= slow.latency_s + 1e-12


# ---------------------------------------------------------------------------
# SSD invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 100), st.sampled_from([16, 32, 64]),
       st.sampled_from([8, 16]))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_sequential(seed, S, chunk):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, H, P, N = 2, 2, 8, 4
    x = jax.random.normal(keys[0], (B, S, H, P))
    log_a = -jax.nn.softplus(jax.random.normal(keys[1], (B, S, H)))
    b = jax.random.normal(keys[2], (B, S, H, N))
    c = jax.random.normal(keys[3], (B, S, H, N))
    y1, f1 = ssd(x, log_a, b, c, chunk=chunk)
    y2, f2 = ssd_ref(x, log_a, b, c)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4,
                               atol=1e-4)
