"""Sharding rules, divisibility guards, collective-byte parsing, and a
small-mesh SPMD integration test (8 fake devices, no dry-run needed)."""

import numpy as np
import pytest

# 8 fake CPU devices for this module ONLY: tests run in a subprocess via
# pytest-forked? No — we spawn a subprocess manually for the mesh test and
# keep everything else single-device.
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.dryrun import collective_bytes
from repro.runtime.sharding import (AxisRules, _divisible_spec,
                                    single_pod_rules, multi_pod_rules)


class TestAxisRules:
    def test_spec_mapping(self):
        rules = single_pod_rules()
        assert rules.spec(("act_batch", None, None)) == P("data")
        assert rules.spec(("embed", "heads", "head_dim")) == \
            P("data", "model")
        assert rules.spec(("unsharded",)) == P()

    def test_multi_pod_batch(self):
        rules = multi_pod_rules()
        assert rules.spec(("act_batch", None)) == P(("pod", "data"))

    def test_overrides(self):
        rules = single_pod_rules().with_overrides(act_seq=None)
        assert rules.spec(("act_batch", "act_seq", None)) == P("data")


class TestDivisibleSpec:
    def _mesh(self):
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        return Mesh(dev, ("data", "model"))

    def test_drops_indivisible(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sizes_mesh = mesh
        # fake a 4x2 mesh via axis size lookup by constructing spec directly
        # (mesh of size 1 divides everything -> keep)
        spec = _divisible_spec(mesh, P("data", "model"), (3, 5))
        assert spec == P("data", "model")

    def test_duplicate_axis_dropped(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = _divisible_spec(mesh, P("model", "model"), (4, 4))
        assert spec == P("model")


class TestCollectiveParser:
    HLO = """
  %all-reduce.1 = bf16[16,512,128]{2,1,0} all-reduce(bf16[16,512,128]{2,1,0} %x), replica_groups={{0,1}}
  %ag = f32[1024,256]{1,0} all-gather(f32[512,256]{1,0} %y), dimensions={0}
  %rs.7 = f32[64]{0} reduce-scatter(f32[128]{0} %z), dimensions={0}
  %a2a = bf16[8,64]{1,0} all-to-all(bf16[8,64]{1,0} %w), dimensions={0}
  %cp = u32[4]{0} collective-permute(u32[4]{0} %v), source_target_pairs={{0,1}}
  %notacoll = f32[9]{0} add(f32[9]{0} %a, f32[9]{0} %b)
"""

    def test_counts_each_kind(self):
        out = collective_bytes(self.HLO)
        assert out["all-reduce"] == 16 * 512 * 128 * 2
        assert out["all-gather"] == 1024 * 256 * 4
        assert out["reduce-scatter"] == 64 * 4
        assert out["all-to-all"] == 8 * 64 * 2
        assert out["collective-permute"] == 4 * 4
        assert out["total"] == sum(out[k] for k in
                                   ("all-reduce", "all-gather",
                                    "reduce-scatter", "all-to-all",
                                    "collective-permute"))

    def test_start_done_not_double_counted(self):
        hlo = """
  %ar0 = bf16[128]{0} all-reduce-start(bf16[128]{0} %x)
  %ar1 = bf16[128]{0} all-reduce-done(bf16[128]{0} %ar0)
"""
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 128 * 2


SPMD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_train_step, input_specs, shardings_for, rules_for
from repro.models import build_model, get_config
from repro.optim import AdamWConfig, init_state
from repro.configs.base import ShapeConfig
from repro.runtime.sharding import use_rules, single_pod_rules

# tiny config, 2x4 mesh: numerics of the sharded train step must match
# the single-device step
cfg = get_config("granite-8b").replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
    vocab=256, remat=False, q_chunk=32, loss_seq_chunk=None)
model = build_model(cfg)
shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = single_pod_rules()

params = model.init(jax.random.PRNGKey(0))
opt = init_state(params)
tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}

from repro.launch.steps import make_train_step
step_plain = jax.jit(make_train_step(model, AdamWConfig(), None, None))
_,_, m0 = step_plain(params, opt, batch)

specs = input_specs(cfg, shape)
sh = shardings_for(cfg, shape, mesh, rules, specs)
with mesh:
    step_spmd = jax.jit(make_train_step(model, AdamWConfig(), rules, mesh),
                        in_shardings=(sh["params"], sh["opt_state"], sh["batch"]))
    _,_, m1 = step_spmd(params, opt, batch)

l0, l1 = float(m0["loss"]), float(m1["loss"])
assert abs(l0 - l1) / abs(l0) < 2e-2, (l0, l1)
print("SPMD_OK", l0, l1)
"""


def test_spmd_matches_single_device(tmp_path):
    """Run the 8-device SPMD parity check in a subprocess (device count must
    be set before jax init)."""
    import subprocess
    import sys
    p = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT], capture_output=True, text=True,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo", timeout=600)
    assert "SPMD_OK" in p.stdout, p.stdout + p.stderr
