"""Pipelined-serving throughput model, min-bottleneck DP, Pareto frontier,
and the Query.pipelines lattice restriction."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, BottleneckLattice, Constraints,
                        CostModel, LATENCY, Link, NetworkModel,
                        PartitionLattice, Query, QueryEngine, Resource,
                        Segment, THROUGHPUT, TRANSFER, benchmark_model,
                        dominates, enumerate_partitions, linear_graph,
                        pareto_frontier, rank)
from repro.core.graph import LayerNode
from repro.core.network import paper_network, THREE_G, FOUR_G, WIRED
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.models import cnn_zoo
from repro.serving.engine import simulate_pipeline_throughput
import repro.core.query as query_mod


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def make_model(n=8, d=16, name="toy"):
    layers = []
    for i in range(n):
        w = jax.random.normal(jax.random.PRNGKey(i), (d, d)) * 0.1
        layers.append(LayerNode(name=f"fc{i}", kind="dense",
                                apply=lambda x, w=w: jnp.tanh(x @ w),
                                flops=2.0 * d * d, param_bytes=4 * d * d))
    return linear_graph(name, _spec(1, d), layers)


def _resources():
    return [Resource("device", "device", RPI4, speed_factor=30.0),
            Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.0),
            Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0)]


@pytest.fixture(scope="module")
def setup():
    graph = make_model()
    resources = _resources()
    db = benchmark_model(graph, resources, AnalyticProvider(), runs=1)
    net = paper_network(FOUR_G, edges=("edge1",), clouds=("cloud",))
    cost = CostModel(db=db, resources=resources, network=net,
                     source="device", input_bytes=150e3)
    return graph, resources, db, net, cost


def _rand_cost(seed, n_blocks=6):
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(n_blocks):
        d = int(rng.integers(4, 16)) * 2
        layers.append(LayerNode(f"l{i}", "dense",
                                apply=lambda x, d=d: jnp.tile(
                                    x[..., :1], (1, d)),
                                flops=float(rng.integers(1, 100)) * 1e6))
    g = linear_graph(f"toy{seed}", _spec(1, 8), layers)
    res = _resources()
    db = benchmark_model(g, res, AnalyticProvider(), runs=1)
    net = NetworkModel(default=Link("l", 0.01, 1e6))
    return CostModel(db=db, resources=res, network=net, source="device",
                     input_bytes=1e5)


class TestThroughputModel:
    def test_bottleneck_is_max_stage(self, setup):
        _, _, db, net, cost = setup
        B = db.n_blocks
        segs = [Segment("device", 0, 1), Segment("edge1", 2, 3),
                Segment("cloud", 4, B - 1)]
        cfg = cost.evaluate(segs)
        stages = [sum(db.time("device", b) for b in (0, 1)),
                  sum(db.time("edge1", b) for b in (2, 3)),
                  sum(db.time("cloud", b) for b in range(4, B)),
                  net.comm_time("device", "edge1", db.output_bytes(1)),
                  net.comm_time("edge1", "cloud", db.output_bytes(3))]
        assert cfg.bottleneck_s == pytest.approx(max(stages))
        assert cfg.throughput_rps == pytest.approx(1.0 / max(stages))
        assert cfg.stage_compute_s == pytest.approx(tuple(stages[:3]))
        assert cfg.stage_comm_s == pytest.approx(tuple(stages[3:]))

    def test_native_source_bottleneck_is_compute(self, setup):
        _, _, db, _, cost = setup
        cfg = cost.evaluate([Segment("device", 0, db.n_blocks - 1)])
        assert cfg.bottleneck_s == pytest.approx(sum(cfg.compute_s.values()))

    def test_input_hop_counts_as_stage(self, setup):
        _, _, db, net, cost = setup
        cfg = cost.evaluate([Segment("cloud", 0, db.n_blocks - 1)])
        assert cfg.bottleneck_s >= net.comm_time("device", "cloud", 150e3)

    def test_rank_top_n_zero_returns_empty(self, setup):
        _, _, _, _, cost = setup
        configs = enumerate_partitions(cost)
        assert rank(configs, LATENCY, top_n=0) == []
        assert len(rank(configs, LATENCY, top_n=None)) == len(configs)
        # every strategy agrees on the top_n=0 edge case
        assert PartitionLattice(cost).solve(top_n=0) == []
        assert BottleneckLattice(cost).solve(top_n=0) == []


class TestBottleneckDP:
    def test_optimum_matches_oracle(self, setup):
        _, _, _, _, cost = setup
        oracle = rank(enumerate_partitions(cost), THROUGHPUT)[0]
        got = BottleneckLattice(cost).solve(top_n=1)[0]
        assert got.bottleneck_s == pytest.approx(oracle.bottleneck_s)

    def test_topn_matches(self, setup):
        _, _, _, _, cost = setup
        oracle = rank(enumerate_partitions(cost), THROUGHPUT, top_n=5)
        got = BottleneckLattice(cost).solve(top_n=5)
        assert len(got) == 5
        for o, g in zip(oracle, got):
            assert g.bottleneck_s == pytest.approx(o.bottleneck_s)

    @pytest.mark.parametrize("seed", range(8))
    def test_random_costs_match_oracle(self, seed):
        cost = _rand_cost(seed)
        oracle = rank(enumerate_partitions(cost), THROUGHPUT)[0]
        got = BottleneckLattice(cost).solve(top_n=1)[0]
        assert abs(got.bottleneck_s - oracle.bottleneck_s) < 1e-12

    def test_must_use_constraint(self, setup):
        _, _, _, _, cost = setup
        cons = Constraints(must_use=("device", "edge1", "cloud"))
        got = BottleneckLattice(cost, cons).solve(top_n=1)[0]
        oracle = rank([c for c in enumerate_partitions(cost)
                       if set(c.resources) >= {"device", "edge1", "cloud"}],
                      THROUGHPUT)[0]
        assert got.bottleneck_s == pytest.approx(oracle.bottleneck_s)
        assert set(got.resources) == {"device", "edge1", "cloud"}

    @pytest.mark.parametrize("seed", range(6))
    def test_min_blocks_on_binding_constraint(self, seed):
        """Regression: a binding path-dependent constraint used to reject
        the whole (truncated) k-best pool and return [] even when feasible
        partitions existed; the widened pool must find the constrained
        optimum."""
        cost = _rand_cost(seed, n_blocks=7)
        cons = Constraints(min_blocks_on={"device": 5})
        feas = [c for c in enumerate_partitions(cost)
                if sum(s.end - s.start + 1 for s in c.segments
                       if s.resource == "device") >= 5]
        oracle = rank(feas, THROUGHPUT)[0]
        got = BottleneckLattice(cost, cons).solve(top_n=1)
        assert got, "binding constraint must not empty the result"
        assert got[0].bottleneck_s == pytest.approx(oracle.bottleneck_s)

    def test_exclude_and_pin(self, setup):
        _, _, _, _, cost = setup
        cons = Constraints(exclude=("cloud",), pin={3: "edge1"})
        for cfg in BottleneckLattice(cost, cons).solve(top_n=3):
            assert "cloud" not in cfg.resources
            seg = next(s for s in cfg.segments if s.start <= 3 <= s.end)
            assert seg.resource == "edge1"

    _zoo_dbs: dict = {}

    @pytest.mark.parametrize("model", ["MobileNet", "ResNet50"])
    @pytest.mark.parametrize("access", [THREE_G, FOUR_G, WIRED])
    def test_cnn_zoo_matches_oracle(self, model, access):
        """Acceptance: the min-bottleneck DP matches exhaustive throughput
        winners on CNN-zoo models under the paper's network conditions."""
        resources = _resources()
        if model not in self._zoo_dbs:
            self._zoo_dbs[model] = benchmark_model(
                cnn_zoo.build(model), resources, AnalyticProvider(), runs=1)
        db = self._zoo_dbs[model]
        net = paper_network(access, edges=("edge1",), clouds=("cloud",))
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3)
        oracle = rank(enumerate_partitions(cost), THROUGHPUT)[0]
        got = BottleneckLattice(cost).solve(top_n=1)[0]
        assert got.bottleneck_s == pytest.approx(oracle.bottleneck_s)


class TestParetoFrontier:
    def test_frontier_is_exact_nondominated_set(self, setup):
        _, _, _, _, cost = setup
        configs = enumerate_partitions(cost)
        front = pareto_frontier(configs)
        # soundness: nothing returned is dominated by any enumerated config
        for f in front:
            assert not any(dominates(c, f) for c in configs)
        # completeness: everything left out is dominated by a frontier member
        fset = {f.segments for f in front}
        for c in configs:
            if c.segments not in fset:
                assert any(dominates(f, c) for f in front)

    def test_engine_frontier_matches_enumeration(self, setup):
        _, resources, db, net, cost = setup
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        res = eng.frontier()
        assert res.strategy == "exhaustive"
        want = pareto_frontier(enumerate_partitions(cost))
        assert {c.segments for c in res.configs} == \
            {c.segments for c in want}
        lats = [c.latency_s for c in res.configs]
        assert lats == sorted(lats)

    def test_frontier_contains_all_single_objective_winners(self, setup):
        _, resources, db, net, cost = setup
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        front = {c.segments for c in eng.frontier().configs}
        for obj in (LATENCY, TRANSFER, THROUGHPUT):
            best = eng.run(Query(top_n=1, objective=obj)).best
            # the winner is non-dominated unless tied with a frontier member
            assert best.segments in front or any(
                not dominates(best, c) and not dominates(c, best)
                for c in eng.frontier().configs)


class TestLatticePipelines:
    PIPES = (("device", "cloud"), ("device", "edge1", "cloud"))

    def _engines(self, setup, monkeypatch):
        _, resources, db, net, _ = setup
        exh = QueryEngine(db, resources, net, "device", 150e3)
        res_exh = exh.run(Query(top_n=4, pipelines=self.PIPES))
        monkeypatch.setattr(query_mod, "EXHAUSTIVE_LIMIT", -1)
        lat = QueryEngine(db, resources, net, "device", 150e3)
        res_lat = lat.run(Query(top_n=4, pipelines=self.PIPES))
        return res_exh, res_lat

    def test_lattice_honors_pipelines(self, setup, monkeypatch):
        res_exh, res_lat = self._engines(setup, monkeypatch)
        assert res_exh.strategy == "exhaustive"
        assert res_lat.strategy == "lattice"
        for cfg in res_lat.configs:
            assert cfg.resources in self.PIPES
        assert [c.segments for c in res_lat.configs] == \
            [c.segments for c in res_exh.configs]

    def test_lattice_throughput_matches_exhaustive(self, setup, monkeypatch):
        _, resources, db, net, _ = setup
        exh = QueryEngine(db, resources, net, "device", 150e3)
        want = exh.run(Query(top_n=3, objective=THROUGHPUT))
        monkeypatch.setattr(query_mod, "EXHAUSTIVE_LIMIT", -1)
        lat = QueryEngine(db, resources, net, "device", 150e3)
        got = lat.run(Query(top_n=3, objective=THROUGHPUT))
        assert got.strategy == "lattice"
        for g, w in zip(got.configs, want.configs):
            assert g.bottleneck_s == pytest.approx(w.bottleneck_s)

    def test_invalid_pipelines_consistent_across_strategies(self, setup,
                                                            monkeypatch):
        """A pipe that is not strictly tier-ascending (or names an unknown
        resource) is unrepresentable; every strategy must agree it yields
        nothing — including the restricted-enumeration branch."""
        _, resources, db, net, _ = setup
        bad = (("edge1", "device"), ("device", "nosuch"))
        exh = QueryEngine(db, resources, net, "device", 150e3)
        assert exh.run(Query(top_n=3, pipelines=bad)).configs == []
        assert exh._search_space(Query(pipelines=bad)) == 0
        monkeypatch.setattr(query_mod, "EXHAUSTIVE_LIMIT", -1)
        lat = QueryEngine(db, resources, net, "device", 150e3)
        assert lat.run(Query(top_n=3, pipelines=bad)).configs == []

    def test_search_space_counts_restricted_space(self, setup):
        _, resources, db, net, _ = setup
        eng = QueryEngine(db, resources, net, "device", 150e3)
        B = db.n_blocks
        want = sum(math.comb(B - 1, len(p) - 1) for p in self.PIPES)
        assert eng._search_space(Query(pipelines=self.PIPES)) == want
        assert eng._search_space() > want


class TestPipelineSimulator:
    def test_simulated_matches_predicted(self, setup):
        _, resources, db, net, _ = setup
        eng = QueryEngine(db, resources, net, "device", 150e3)
        for cfg in eng.run(Query(top_n=5)).configs:
            sim = simulate_pipeline_throughput(cfg, n_requests=256)
            assert sim == pytest.approx(cfg.throughput_rps, rel=0.01)

    def test_single_stage_rate(self, setup):
        _, _, db, _, cost = setup
        cfg = cost.evaluate([Segment("device", 0, db.n_blocks - 1)])
        sim = simulate_pipeline_throughput(cfg, n_requests=64)
        assert sim == pytest.approx(1.0 / sum(cfg.compute_s.values()),
                                    rel=0.01)
