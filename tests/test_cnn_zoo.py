"""CNN zoo: topology class, runnability, partition-point structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fuse_blocks
from repro.models import cnn_zoo

FAST = ["VGG16", "ResNet50", "MobileNet", "MobileNetV2", "DenseNet121",
        "InceptionV3", "Xception"]


@pytest.mark.parametrize("name", FAST)
def test_graph_builds_and_runs(name):
    g = cnn_zoo.build(name)
    blocks = fuse_blocks(g)
    x = jnp.zeros(g.input_spec.shape, g.input_spec.dtype)
    for b in blocks[:3]:        # run the first few blocks end to end
        x = b.make_callable()(x)
        assert np.all(np.isfinite(np.asarray(x)))
        assert x.shape == b.out_spec.shape


@pytest.mark.parametrize("name", sorted(cnn_zoo.ZOO))
def test_topology_class_matches_table1(name):
    g = cnn_zoo.build(name)
    blocks = fuse_blocks(g)
    n_points = len(blocks) - 1
    if name in cnn_zoo.LINEAR:
        # every internal layer edge is a cut in a linear model
        assert n_points == g.n_layers - 2
    else:
        # branching: fusion must reduce the cut count below the layer count
        assert n_points < g.n_layers - 2, name
    assert n_points >= 4, (name, n_points)   # NASNet lower bound (Table I)


def test_resnet50_block_structure():
    g = cnn_zoo.build("ResNet50")
    blocks = fuse_blocks(g)
    # 16 residual blocks + stem/pool/head segments; Table I reports 23
    # partition points for Keras ResNet50 (which counts BN/act separately —
    # our conv nodes fuse them, so points come from the same residual cuts)
    assert 18 <= len(blocks) <= 26, len(blocks)


def test_vgg16_partition_points():
    g = cnn_zoo.build("VGG16")
    # paper Table I: 21 partition points for VGG16's 23 layers
    assert len(g.partition_points()) == g.n_layers - 2


def test_output_sizes_decrease_then_flatten():
    """Fig 3's qualitative property: late layers output far less data than
    early conv layers — the reason edge offloading works at all."""
    g = cnn_zoo.build("VGG16")
    blocks = fuse_blocks(g)
    sizes = [b.output_bytes for b in blocks]
    assert max(sizes[:5]) > 20 * sizes[-1]
