"""Serving engine: continuous batching, ragged lengths, pool recycling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model, get_config
from repro.serving import KVCachePool, Request, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("granite-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128, remat=False, q_chunk=32, loss_seq_chunk=None)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


class TestPool:
    def test_acquire_release(self, small_model):
        _, model, _ = small_model
        pool = KVCachePool(model, width=2, max_len=16)
        a = pool.acquire(1)
        b = pool.acquire(2)
        assert {a, b} == {0, 1}
        assert pool.acquire(3) is None
        pool.release(a)
        assert pool.acquire(3) == a


class TestEngine:
    def test_serves_all_requests(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, width=2, max_len=32)
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab,
                                                   int(rng.integers(3, 8))),
                        max_new_tokens=4) for i in range(5)]
        for r in reqs:
            eng.submit(r)
        done = eng.run()
        assert len(done) == 5
        assert all(len(r.tokens) == 4 for r in done)
        assert all(r.first_token_at is not None for r in done)
        # measured throughput is reported for the completed run
        assert eng.stats.requests == 5
        assert eng.stats.tokens == 20
        assert eng.stats.wall_s > 0
        assert eng.measured_throughput_rps == pytest.approx(
            5 / eng.stats.wall_s)
        assert eng.stats.tokens_per_s == pytest.approx(20 / eng.stats.wall_s)

    def test_matches_unbatched_greedy(self, small_model):
        """Continuous-batched decode must equal one-at-a-time greedy."""
        cfg, model, params = small_model
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, cfg.vocab, 6),
                   rng.integers(0, cfg.vocab, 4)]
        n_new = 5

        # reference: sequential greedy via prefill+decode per request
        def greedy(prompt):
            cache = model.init_cache(batch=1, max_len=32)
            logits, cache = jax.jit(model.prefill)(
                params, jnp.asarray(prompt, jnp.int32)[None], cache)
            toks = [int(jnp.argmax(logits[0, -1]))]
            clen = len(prompt)
            step = jax.jit(model.decode_step)
            for _ in range(n_new - 1):
                logits, cache = step(
                    params, jnp.asarray([[toks[-1]]], jnp.int32), cache,
                    jnp.int32(clen))
                toks.append(int(jnp.argmax(logits[0, -1])))
                clen += 1
            return toks

        want = [greedy(p) for p in prompts]

        eng = ServingEngine(model, params, width=2, max_len=32)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
        done = sorted(eng.run(), key=lambda r: r.rid)
        for r, w in zip(done, want):
            assert r.tokens == w, (r.rid, r.tokens, w)

    def test_width_from_operating_point(self, small_model):
        """An engine built from a Scission operating point admits exactly
        the batch size the cost model priced."""
        from repro.core.partition import PartitionConfig, Segment
        cfg, model, params = small_model
        point = PartitionConfig(
            model="lm", segments=(Segment("cloud", 0, 3),), latency_s=0.1,
            compute_s={"cloud": 0.1}, comm_s=0.0, transfer_bytes=0.0,
            stage_compute_s=(0.1,), batch_size=3, replicas=(2,))
        eng = ServingEngine(model, params, max_len=32, config=point)
        assert eng.width == 3
        assert eng.pool.width == 3
        assert eng.config is point
        # explicit width always wins over the operating point
        eng2 = ServingEngine(model, params, width=2, max_len=32,
                             config=point)
        assert eng2.width == 2
        with pytest.raises(ValueError, match="width"):
            ServingEngine(model, params, width=0, max_len=32)

    def test_slot_reuse_more_requests_than_width(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, width=1, max_len=32)
        rng = np.random.default_rng(3)
        for i in range(3):
            eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4),
                               max_new_tokens=3))
        done = eng.run()
        assert len(done) == 3
