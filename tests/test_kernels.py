"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Sweeps shapes/dtypes per the assignment; tolerances follow the usual
bf16-kernel practice (rtol ~2e-2 bf16, 1e-5 fp32).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import decode_attention
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ref import (decode_attention_ref, flash_attention_ref,
                               ssd_ref)

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _qkv(key, B, Sq, Sk, H, Hk, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hk, hd), jnp.float32).astype(dtype)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,Hk,hd", [
        (1, 256, 4, 4, 64),       # MHA
        (2, 256, 8, 2, 64),       # GQA 4:1
        (1, 512, 4, 1, 128),      # MQA, bigger head
        (1, 128, 2, 2, 256),      # gemma-style head_dim
    ])
    def test_causal_matches_ref(self, B, S, H, Hk, hd, dtype):
        q, k, v = _qkv(jax.random.PRNGKey(0), B, S, S, H, Hk, hd, dtype)
        got = flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                              interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_non_causal(self):
        q, k, v = _qkv(jax.random.PRNGKey(1), 2, 256, 256, 4, 4, 64,
                       jnp.float32)
        got = flash_attention(q, k, v, causal=False, interpret=True)
        want = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **TOL[jnp.float32])

    @pytest.mark.parametrize("window", [64, 128, 200])
    def test_sliding_window(self, window):
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 512, 512, 4, 2, 64,
                       jnp.float32)
        got = flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
        want = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **TOL[jnp.float32])

    def test_softcap(self):
        q, k, v = _qkv(jax.random.PRNGKey(3), 1, 256, 256, 4, 2, 64,
                       jnp.float32)
        got = flash_attention(q, k, v, causal=True, softcap=50.0,
                              interpret=True)
        want = flash_attention_ref(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **TOL[jnp.float32])

    def test_uneven_blocks(self):
        q, k, v = _qkv(jax.random.PRNGKey(4), 1, 384, 384, 2, 2, 64,
                       jnp.float32)
        got = flash_attention(q, k, v, causal=True, block_q=128, block_k=64,
                              interpret=True)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   **TOL[jnp.float32])


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,Smax,H,Hk,hd", [
        (2, 512, 4, 4, 64),
        (4, 1024, 8, 2, 64),
        (1, 512, 8, 1, 128),
    ])
    def test_matches_ref(self, B, Smax, H, Hk, hd, dtype):
        ks = jax.random.split(jax.random.PRNGKey(5), 4)
        q = jax.random.normal(ks[0], (B, H, hd), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (B, Smax, Hk, hd),
                              jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (B, Smax, Hk, hd),
                              jnp.float32).astype(dtype)
        lengths = jax.random.randint(ks[3], (B,), 1, Smax + 1,
                                     dtype=jnp.int32)
        got = decode_attention(q, k, v, lengths, block_k=256, interpret=True)
        want = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **TOL[dtype])

    def test_length_masking_exact(self):
        """Entries past `length` must not influence the output at all."""
        B, Smax, H, hd = 1, 512, 2, 64
        ks = jax.random.split(jax.random.PRNGKey(6), 3)
        q = jax.random.normal(ks[0], (B, H, hd))
        k = jax.random.normal(ks[1], (B, Smax, H, hd))
        v = jax.random.normal(ks[2], (B, Smax, H, hd))
        lengths = jnp.array([300], jnp.int32)
        got = decode_attention(q, k, v, lengths, interpret=True)
        k2 = k.at[:, 300:].set(1e6)
        v2 = v.at[:, 300:].set(-1e6)
        got2 = decode_attention(q, k2, v2, lengths, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(got2),
                                   rtol=1e-6)


class TestSSDScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (1, 256, 2, 64, 64, 64),
        (2, 256, 4, 64, 32, 128),
        (1, 512, 1, 128, 64, 128),
    ])
    def test_matches_sequential_ref(self, B, S, H, P, N, chunk, dtype):
        ks = jax.random.split(jax.random.PRNGKey(7), 4)
        x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32).astype(dtype)
        log_a = -jax.nn.softplus(
            jax.random.normal(ks[1], (B, S, H), jnp.float32))
        b = jax.random.normal(ks[2], (B, S, H, N), jnp.float32).astype(dtype)
        c = jax.random.normal(ks[3], (B, S, H, N), jnp.float32).astype(dtype)
        y, fin = ssd_scan(x, log_a, b, c, chunk=chunk, interpret=True)
        y_ref, fin_ref = ssd_ref(x, log_a, b, c)
        tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
            dict(rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(fin), np.asarray(fin_ref),
                                   rtol=1e-3, atol=1e-3)

    def test_matches_model_ssd(self):
        """Kernel vs the models/ssm.py chunked-jnp implementation."""
        from repro.models.ssm import ssd as model_ssd
        ks = jax.random.split(jax.random.PRNGKey(8), 4)
        B, S, H, P, N = 2, 256, 2, 64, 64
        x = jax.random.normal(ks[0], (B, S, H, P))
        log_a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
        b = jax.random.normal(ks[2], (B, S, H, N))
        c = jax.random.normal(ks[3], (B, S, H, N))
        y1, f1 = ssd_scan(x, log_a, b, c, chunk=64, interpret=True)
        y2, f2 = model_ssd(x, log_a, b, c, chunk=64)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                                   rtol=1e-4, atol=1e-4)
