"""Benchmarking harness: providers, DB round-trip, additivity assumption."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, BenchmarkDB, CompiledCostProvider,
                        Resource, TimingProvider, benchmark_model,
                        fuse_blocks, linear_graph)
from repro.core.graph import LayerNode
from repro.core.resources import CLOUD_VM, RPI4


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def make_model(n=4, d=64, name="benchtoy"):
    layers = []
    for i in range(n):
        w = jax.random.normal(jax.random.PRNGKey(i), (d, d)) * 0.1
        layers.append(LayerNode(name=f"fc{i}", kind="dense",
                                apply=lambda x, w=w: jnp.tanh(x @ w),
                                flops=2.0 * d * d, param_bytes=4 * d * d))
    return linear_graph(name, _spec(1, d), layers)


RES = [Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0),
       Resource("device", "device", RPI4, speed_factor=30.0)]


class TestProviders:
    @pytest.mark.flaky(reruns=3)
    def test_timing_provider_positive_and_scaled(self):
        g = make_model()
        db = benchmark_model(g, RES, TimingProvider(), runs=3)
        ratios = []
        for b in range(db.n_blocks):
            t_cloud = db.time("cloud", b)
            t_dev = db.time("device", b)
            assert t_cloud > 0 and t_dev > 0
            ratios.append(t_dev / t_cloud)
        # speed_factor 30 vs 1; wall-clock jitter on a shared host can be
        # large per block — require the median ratio to be in the ballpark
        ratios.sort()
        assert 5 < ratios[len(ratios) // 2] < 200, ratios

    def test_compiled_cost_provider_flops(self):
        g = make_model(n=2, d=64)
        db = benchmark_model(g, RES[:1], CompiledCostProvider(), runs=1)
        rec = db.records["cloud"][1]  # pure single-matmul block
        # tanh(x @ w): matmul 2*1*64*64 flops dominate
        assert rec.flops >= 2 * 64 * 64
        assert rec.mean_time_s > 0

    def test_analytic_provider_roofline(self):
        g = make_model(n=1, d=64)
        db = benchmark_model(g, RES, AnalyticProvider(), runs=1)
        blk = fuse_blocks(g)[0]
        want = RPI4.layer_time(
            blk.flops,
            blk.param_bytes + 64 * 4 + blk.output_bytes)
        assert db.time("device", 0) == pytest.approx(want)


class TestDB:
    def test_json_roundtrip(self):
        g = make_model()
        db = benchmark_model(g, RES, AnalyticProvider(), runs=1)
        db2 = BenchmarkDB.from_json(db.to_json())
        assert db2.model == db.model and db2.n_blocks == db.n_blocks
        np.testing.assert_allclose(db2.times_matrix(["cloud", "device"]),
                                   db.times_matrix(["cloud", "device"]))
        np.testing.assert_allclose(db2.out_bytes_vector(),
                                   db.out_bytes_vector())

    def test_matrix_shape(self):
        g = make_model(n=5)
        db = benchmark_model(g, RES, AnalyticProvider(), runs=1)
        assert db.times_matrix(["cloud", "device"]).shape == (2, db.n_blocks)


class TestAdditivityAssumption:
    """Paper §III-A assumption 2: total inference time ≈ Σ block times.

    Validated on wall-clock: run the full model jit'd end-to-end and compare
    with the sum of independently-benchmarked blocks.  Per-layer dispatch
    makes the sum an over-estimate; we assert agreement within 3x (CPU jitter
    on a shared host) and record the measured ratio for EXPERIMENTS.md.
    """

    @pytest.mark.flaky(reruns=3)
    def test_sum_of_blocks_approximates_total(self):
        d, n = 256, 6
        g = make_model(n=n, d=d, name="additivity")
        db = benchmark_model(g, RES[:1], TimingProvider(), runs=5)
        block_sum = sum(db.time("cloud", b) for b in range(db.n_blocks))

        # full-model wall clock
        blocks = fuse_blocks(g)
        fns = [b.make_callable() for b in blocks]

        def full(x):
            for f in fns:
                x = f(x)
            return x

        jf = jax.jit(full)
        x = jnp.zeros((1, d))
        jax.block_until_ready(jf(x))
        samples = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(jf(x))
            samples.append(time.perf_counter() - t0)
        total = min(samples)
        ratio = block_sum / total
        assert 1 / 3 < ratio < 10, f"additivity ratio {ratio:.2f}"
