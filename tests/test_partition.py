"""Partitioning: exhaustive oracle vs DP lattice, cost model, constraints."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, BenchmarkDB, Constraints, CostModel,
                        LATENCY, Link, NetworkModel, Objective,
                        PartitionLattice, Query, QueryEngine, Resource,
                        Segment, TRANSFER, benchmark_model,
                        enumerate_partitions, linear_graph, ordered_pipelines,
                        paper_testbed, rank)
from repro.core.graph import LayerNode
from repro.core.network import paper_network, FOUR_G, THREE_G
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4, DeviceModel


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def make_model(n=8, d=16, name="toy"):
    layers = []
    for i in range(n):
        w = jax.random.normal(jax.random.PRNGKey(i), (d, d)) * 0.1
        layers.append(LayerNode(name=f"fc{i}", kind="dense",
                                apply=lambda x, w=w: jnp.tanh(x @ w),
                                flops=2.0 * d * d, param_bytes=4 * d * d))
    return linear_graph(name, _spec(1, d), layers)


@pytest.fixture(scope="module")
def setup():
    graph = make_model()
    resources = [
        Resource("device", "device", RPI4, speed_factor=30.0),
        Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.0),
        Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0),
    ]
    db = benchmark_model(graph, resources, AnalyticProvider(), runs=1)
    net = paper_network(FOUR_G, edges=("edge1",), clouds=("cloud",))
    cost = CostModel(db=db, resources=resources, network=net,
                     source="device", input_bytes=150e3)
    return graph, resources, db, net, cost


class TestCostModel:
    def test_native_device_has_no_comm(self, setup):
        _, _, db, _, cost = setup
        cfg = cost.evaluate([Segment("device", 0, db.n_blocks - 1)])
        assert cfg.comm_s == 0.0 and cfg.input_comm_s == 0.0
        assert cfg.transfer_bytes == 0.0

    def test_native_cloud_pays_input_transfer(self, setup):
        _, _, db, net, cost = setup
        cfg = cost.evaluate([Segment("cloud", 0, db.n_blocks - 1)])
        assert cfg.input_comm_s == pytest.approx(
            net.comm_time("device", "cloud", 150e3))
        assert cfg.transfer_bytes == 150e3

    def test_latency_is_additive(self, setup):
        """Paper assumption 2: end-to-end = Σ compute + Σ comm."""
        _, _, db, net, cost = setup
        B = db.n_blocks
        segs = [Segment("device", 0, 1), Segment("edge1", 2, 3),
                Segment("cloud", 4, B - 1)]
        cfg = cost.evaluate(segs)
        manual = (sum(db.time("device", b) for b in (0, 1))
                  + sum(db.time("edge1", b) for b in (2, 3))
                  + sum(db.time("cloud", b) for b in range(4, B))
                  + net.comm_time("device", "edge1", db.output_bytes(1))
                  + net.comm_time("edge1", "cloud", db.output_bytes(3)))
        assert cfg.latency_s == pytest.approx(manual)


class TestExhaustive:
    def test_pipeline_count(self, setup):
        _, resources, *_ = setup
        pipes = ordered_pipelines(resources)
        # 1 device x 1 edge x 1 cloud: 2*2*2 - 1 = 7 pipelines
        assert len(pipes) == 7

    def test_config_count(self, setup):
        _, _, db, _, cost = setup
        B = db.n_blocks
        configs = enumerate_partitions(cost)
        want = sum(math.comb(B - 1, k - 1)
                   for k in (1, 1, 1, 2, 2, 2, 3))
        assert len(configs) == want

    def test_segments_cover_blocks(self, setup):
        _, _, db, _, cost = setup
        for cfg in enumerate_partitions(cost):
            covered = [b for s in cfg.segments
                       for b in range(s.start, s.end + 1)]
            assert covered == list(range(db.n_blocks))


class TestLatticeVsOracle:
    def test_unconstrained_optimum_matches(self, setup):
        _, _, _, _, cost = setup
        oracle = rank(enumerate_partitions(cost), LATENCY)[0]
        got = PartitionLattice(cost).solve(top_n=1)[0]
        assert got.latency_s == pytest.approx(oracle.latency_s)

    def test_topn_matches(self, setup):
        _, _, _, _, cost = setup
        oracle = rank(enumerate_partitions(cost), LATENCY, top_n=5)
        got = PartitionLattice(cost).solve(top_n=5)
        assert len(got) == 5
        for o, g in zip(oracle, got):
            assert g.latency_s == pytest.approx(o.latency_s)

    def test_must_use_all(self, setup):
        _, _, _, _, cost = setup
        cons = Constraints(must_use=("device", "edge1", "cloud"))
        got = PartitionLattice(cost, cons).solve(top_n=1)[0]
        oracle = rank([c for c in enumerate_partitions(cost)
                       if set(c.resources) >= {"device", "edge1", "cloud"}],
                      LATENCY)[0]
        assert got.latency_s == pytest.approx(oracle.latency_s)
        assert set(got.resources) == {"device", "edge1", "cloud"}

    def test_exclude(self, setup):
        _, _, _, _, cost = setup
        cons = Constraints(exclude=("cloud",))
        for cfg in PartitionLattice(cost, cons).solve(top_n=3):
            assert "cloud" not in cfg.resources

    def test_pin_block(self, setup):
        _, _, _, _, cost = setup
        cons = Constraints(pin={3: "edge1"})
        cfg = PartitionLattice(cost, cons).solve(top_n=1)[0]
        seg = next(s for s in cfg.segments if s.start <= 3 <= s.end)
        assert seg.resource == "edge1"

    def test_max_link_bytes(self, setup):
        _, _, db, _, cost = setup
        tiny = 1.0  # bytes — forbids any device->edge handoff and input xfer
        cons = Constraints(max_link_bytes={("device", "edge1"): tiny,
                                           ("device", "cloud"): tiny})
        for cfg in PartitionLattice(cost, cons).solve(top_n=3):
            assert cfg.resources == ("device",)

    def test_transfer_objective(self, setup):
        _, _, _, _, cost = setup
        cfg = PartitionLattice(cost, objective=TRANSFER).solve(top_n=1)[0]
        # minimal transfer = stay on the source device
        assert cfg.resources == ("device",)
        assert cfg.transfer_bytes == 0.0


class TestQueryEngine:
    def test_query_under_50ms(self, setup):
        _, resources, db, net, _ = setup
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        eng.run()  # warm the cache (paper: queries run on cached bench data)
        res = eng.run(Query(top_n=3, must_use=("edge1",)))
        assert res.query_time_s < 0.050
        assert len(res.configs) == 3

    def test_network_flip(self, setup):
        """Figures 6-8: the optimum flips with the network condition when the
        device is slow relative to the link."""
        graph, resources, db, _, _ = setup
        slow = paper_network(THREE_G, edges=("edge1",), clouds=("cloud",))
        fast = NetworkModel().connect("device", "cloud",
                                      Link("lan", 1e-3, 1e9))
        e_slow = QueryEngine(db, resources, slow, "device", 150e3)
        e_fast = QueryEngine(db, resources, fast, "device", 150e3)
        best_slow = e_slow.run(Query(top_n=1)).best
        best_fast = e_fast.run(Query(top_n=1)).best
        # On a near-free link the cloud should win; on 3G it must not be
        # *more* cloud-heavy than the fast-link optimum.
        assert best_fast.resources == ("cloud",)
        cloud_blocks = lambda c: sum(
            s.end - s.start + 1 for s in c.segments if s.resource == "cloud")
        assert cloud_blocks(best_slow) <= cloud_blocks(best_fast)
