"""Batch-indexed benchmark profiles, replica-aware stage rates, and
frontier-driven operating points (the batch/replica cost-model refactor)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, BenchmarkDB, BlockBenchmark,
                        BottleneckLattice, CostModel, Link, NetworkModel,
                        Query, QueryEngine, Resource, Scission, Segment,
                        THROUGHPUT, benchmark_model, enumerate_partitions,
                        linear_graph, rank, trim_replicas)
from repro.core.bench import SCHEMA_VERSION, _interp_profile
from repro.core.graph import LayerNode
from repro.core.network import paper_network, THREE_G
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.runtime.elastic import ElasticController
from repro.serving.engine import simulate_pipeline_throughput


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def make_model(n=8, d=64, name="toy"):
    layers = []
    for i in range(n):
        w = jax.random.normal(jax.random.PRNGKey(i), (d, d)) * 0.1
        layers.append(LayerNode(name=f"fc{i}", kind="dense",
                                apply=lambda x, w=w: jnp.tanh(x @ w),
                                flops=2.0 * d * d, param_bytes=4 * d * d))
    return linear_graph(name, _spec(1, d), layers)


def _resources():
    return [Resource("device", "device", RPI4, speed_factor=30.0),
            Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.0),
            Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0)]


@pytest.fixture(scope="module")
def setup():
    graph = make_model()
    resources = _resources()
    db = benchmark_model(graph, resources, AnalyticProvider(), runs=1,
                         batch_sizes=(1, 4, 16))
    net = paper_network(THREE_G, edges=("edge1",), clouds=("cloud",))
    return graph, resources, db, net


# ---------------------------------------------------------------------------
# benchmark DB: profiles, interpolation, schema versions
# ---------------------------------------------------------------------------

class TestBatchProfiles:
    def test_profile_measured_points(self, setup):
        _, _, db, _ = setup
        assert db.measured_batches() == [1, 4, 16]
        assert db.max_batch() == 16
        rec = db.records["device"][0]
        assert set(rec.batch_profile) == {1, 4, 16}
        # batch-1 scalars mirror the profile's batch-1 point
        assert rec.batch_profile[1] == (rec.mean_time_s, rec.output_bytes)

    def test_time_exact_at_measured_batches(self, setup):
        _, _, db, _ = setup
        rec = db.records["device"][0]
        for b in (1, 4, 16):
            assert db.time("device", 0, batch=b) == \
                pytest.approx(rec.batch_profile[b][0])

    def test_time_interpolates_between_measured(self, setup):
        _, _, db, _ = setup
        t4 = db.time("device", 0, batch=4)
        t16 = db.time("device", 0, batch=16)
        t8 = db.time("device", 0, batch=8)
        assert min(t4, t16) <= t8 <= max(t4, t16)
        # strictly between when the profile is strictly monotone
        if t4 < t16:
            assert t4 < t8 < t16

    def test_time_clamps_never_extrapolates(self, setup):
        _, _, db, _ = setup
        assert db.time("device", 0, batch=64) == \
            pytest.approx(db.time("device", 0, batch=16))
        assert db.time("device", 0, batch=1) == \
            pytest.approx(db.records["device"][0].mean_time_s)

    def test_output_bytes_scale_with_batch(self, setup):
        _, _, db, _ = setup
        per_req = db.output_bytes(0)
        assert db.output_bytes(0, batch=4) == 4 * per_req
        np.testing.assert_allclose(db.out_bytes_vector(batch=4),
                                   4 * db.out_bytes_vector())

    def test_interp_log_linear_midpoint(self):
        # log-linear: at the geometric midpoint of the batch range the value
        # is the geometric mean of the endpoint values
        profile = {1: (1.0, 10), 16: (4.0, 160)}
        assert _interp_profile(profile, 4) == pytest.approx(2.0)

    def test_measured_batches_ignores_stale_resources(self, setup):
        """Regression: a departed resource's stale batch-1-only records
        must not mask batches the active testbed did measure (the global
        intersection collapsed to {1} and upgrade loops never converged)."""
        _, resources, db, net = setup
        db2 = BenchmarkDB.from_json(db.to_json())
        db2.records["old_edge"] = [
            BlockBenchmark(block=r.block, resource="old_edge",
                           mean_time_s=r.mean_time_s, std_time_s=0.0,
                           output_bytes=r.output_bytes, runs=1)
            for r in db2.records["device"]]
        assert db2.measured_batches() == [1]        # global intersection
        names = [r.name for r in resources]
        assert db2.measured_batches(names) == [1, 4, 16]
        assert db2.max_batch(names) == 16
        # an engine over the live testbed still sweeps the full profile and
        # accepts its operating points
        eng = QueryEngine(db2, resources, net, source="device",
                          input_bytes=150e3)
        assert eng._frontier_batches(Query()) == [1, 4, 16]
        assert eng.run(Query(top_n=1, batch_size=16)).best.batch_size == 16

    def test_operating_point_caches_bounded(self, setup):
        _, resources, db, net = setup
        from repro.core.query import CACHE_POINTS
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        for n in range(2, CACHE_POINTS + 6):
            eng.run(Query(top_n=1, replicas={"device": n}))
        assert len(eng._costs) <= CACHE_POINTS
        assert len(eng._exhaustive_cache) <= CACHE_POINTS

    def test_benchmark_model_always_measures_batch_one(self, setup):
        graph, resources, _, _ = setup
        db = benchmark_model(graph, resources[:1], AnalyticProvider(),
                             runs=1, batch_sizes=(8,))
        assert db.measured_batches() == [1, 8]

    def test_benchmark_model_rejects_bad_batches(self, setup):
        graph, resources, _, _ = setup
        with pytest.raises(ValueError, match="batch sizes"):
            benchmark_model(graph, resources[:1], AnalyticProvider(),
                            runs=1, batch_sizes=(0,))

    def test_benchmark_batches_incremental_merge(self, setup):
        """Regression: upgrading a cached DB with new batch sizes used to
        re-time the whole sweep; the incremental path measures only the
        missing batches and leaves existing profile points untouched."""
        graph, resources, _, _ = setup

        class Counting(AnalyticProvider):
            calls: list = []

            def measure(self, block, resource, runs, batch=1):
                Counting.calls.append(batch)
                return super().measure(block, resource, runs, batch=batch)

        from repro.core import benchmark_batches
        db = benchmark_model(graph, resources[:1], Counting(), runs=1,
                             batch_sizes=(1, 4))
        before = {b: dict(r.batch_profile)
                  for b, r in enumerate(db.records["device"])}
        Counting.calls = []
        benchmark_batches(db, graph, resources[:1], Counting(), runs=1,
                          batch_sizes=(4, 8))
        assert set(Counting.calls) == {8}          # 4 already measured
        assert db.measured_batches() == [1, 4, 8]
        for b, rec in enumerate(db.records["device"]):
            for batch, point in before[b].items():  # old points untouched
                assert rec.batch_profile[batch] == point
        with pytest.raises(KeyError, match="edge1"):
            benchmark_batches(db, graph, resources[:2], Counting(), runs=1,
                              batch_sizes=(8,))

    def test_legacy_provider_without_batch_kwarg(self, setup):
        graph, resources, _, _ = setup

        class Legacy:
            def measure(self, block, resource, runs):
                return 1e-3, 0.0, 0.0, 0.0

        db = benchmark_model(graph, resources[:1], Legacy(), runs=1)
        assert db.measured_batches() == [1]
        with pytest.raises(TypeError, match="batch"):
            benchmark_model(graph, resources[:1], Legacy(), runs=1,
                            batch_sizes=(1, 4))


class TestSchemaVersions:
    def test_v2_roundtrip_bit_exact(self, setup):
        _, _, db, _ = setup
        s = db.to_json()
        assert json.loads(s)["schema_version"] == SCHEMA_VERSION
        db2 = BenchmarkDB.from_json(s)
        assert db2.to_json() == s
        for r, recs in db.records.items():
            for a, b in zip(recs, db2.records[r]):
                assert a == b

    def test_v1_loads_as_batch1_profile(self, setup):
        _, _, db, _ = setup
        payload = json.loads(db.to_json())
        payload.pop("schema_version")           # v1: implicit version
        for recs in payload["records"].values():
            for rec in recs:
                rec.pop("batch_profile")
        old = BenchmarkDB.from_json(json.dumps(payload))
        assert old.measured_batches() == [1]
        for r in old.records:
            for a, b in zip(old.records[r], db.records[r]):
                assert a.mean_time_s == b.mean_time_s
                assert a.output_bytes == b.output_bytes
                assert a.batch_profile == {1: (b.mean_time_s,
                                               b.output_bytes)}
        # batch queries against a migrated DB clamp to the batch-1 point
        assert old.time("device", 0, batch=8) == \
            pytest.approx(old.time("device", 0))

    def test_future_schema_rejected(self, setup):
        _, _, db, _ = setup
        payload = json.loads(db.to_json())
        payload["schema_version"] = SCHEMA_VERSION + 1
        with pytest.raises(ValueError, match="schema_version"):
            BenchmarkDB.from_json(json.dumps(payload))

    def test_empty_db_output_bytes_clear_error(self):
        db = BenchmarkDB(model="empty", n_blocks=3)
        with pytest.raises(KeyError, match="no records"):
            db.output_bytes(0)


# ---------------------------------------------------------------------------
# replica/batch-aware cost model
# ---------------------------------------------------------------------------

class TestEffectiveRates:
    def test_bottleneck_divides_by_replicas_and_batch(self, setup):
        _, resources, db, net = setup
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3, batch_size=4,
                         replica_budget={"device": 3, "cloud": 2})
        B = db.n_blocks
        cfg = cost.evaluate([Segment("device", 0, 3),
                             Segment("cloud", 4, B - 1)])
        assert cfg.batch_size == 4 and cfg.replicas == (3, 2)
        dev_t = sum(db.time("device", b, 4) for b in range(4))
        cld_t = sum(db.time("cloud", b, 4) for b in range(4, B))
        hop = net.comm_time("device", "cloud", db.output_bytes(3, batch=4))
        periods = [dev_t / (3 * 4), cld_t / (2 * 4), hop / 4]
        assert cfg.bottleneck_s == pytest.approx(max(periods))
        assert cfg.throughput_rps == pytest.approx(1.0 / max(periods))
        # latency stays the per-batch end-to-end time (replicas don't help)
        assert cfg.latency_s == pytest.approx(dev_t + cld_t + hop)

    def test_batch1_single_replica_unchanged(self, setup):
        _, resources, db, net = setup
        plain = CostModel(db=db, resources=resources, network=net,
                          source="device", input_bytes=150e3)
        cfg = plain.evaluate([Segment("device", 0, db.n_blocks - 1)])
        assert cfg.batch_size == 1 and cfg.replicas == (1,)
        assert cfg.bottleneck_s == pytest.approx(
            sum(cfg.compute_s.values()))

    def test_replicas_never_hurt_throughput(self, setup):
        _, resources, db, net = setup
        base = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3)
        repl = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3,
                         replica_budget={"device": 4})
        for a, b in zip(enumerate_partitions(base),
                        enumerate_partitions(repl)):
            assert b.throughput_rps >= a.throughput_rps - 1e-12
            assert b.latency_s == pytest.approx(a.latency_s)

    def test_invalid_operating_points_rejected(self, setup):
        _, resources, db, net = setup
        with pytest.raises(ValueError, match="batch_size"):
            CostModel(db=db, resources=resources, network=net,
                      source="device", input_bytes=1.0, batch_size=0)
        with pytest.raises(ValueError, match="replica budget"):
            CostModel(db=db, resources=resources, network=net,
                      source="device", input_bytes=1.0,
                      replica_budget={"device": 0})

    def test_batch_beyond_measured_rejected(self, setup):
        """Regression: pricing batch b from a profile clamped at max_batch
        would divide the clamped time by b — linear throughput extrapolation
        the measurements don't support.  The operating point is refused."""
        _, resources, db, net = setup
        with pytest.raises(ValueError, match="largest measured batch"):
            CostModel(db=db, resources=resources, network=net,
                      source="device", input_bytes=1.0, batch_size=64)
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        with pytest.raises(ValueError, match="largest measured batch"):
            eng.run(Query(batch_size=64))
        # frontier applies the same fail-fast contract to explicit sweeps —
        # a silently-dropped candidate would read as "evaluated and
        # dominated" when it was never priced at all
        with pytest.raises(ValueError, match="outside the measured"):
            eng.frontier(Query(batch_sizes=(1, 64)))

    @pytest.mark.parametrize("batch,budget", [
        (1, {"device": 2}),
        (4, {}),
        (4, {"device": 2, "edge1": 3}),
        (16, {"cloud": 2}),
    ])
    def test_dp_matches_oracle_at_operating_point(self, setup, batch, budget):
        """The min-bottleneck DP stays exact when batch and replicas only
        rescale each state's local cost."""
        _, resources, db, net = setup
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3,
                         batch_size=batch, replica_budget=budget)
        oracle = rank(enumerate_partitions(cost), THROUGHPUT)[0]
        got = BottleneckLattice(cost).solve(top_n=1)[0]
        assert got.bottleneck_s == pytest.approx(oracle.bottleneck_s)

    def test_trim_replicas_keeps_bottleneck(self, setup):
        _, resources, db, net = setup
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3,
                         replica_budget={"device": 8, "cloud": 8})
        for cfg in enumerate_partitions(cost):
            trimmed = trim_replicas(cfg)
            assert trimmed.bottleneck_s == pytest.approx(cfg.bottleneck_s)
            assert all(t <= r for t, r in zip(trimmed.replicas,
                                              cfg.replicas))


# ---------------------------------------------------------------------------
# frontier operating points + acceptance criterion
# ---------------------------------------------------------------------------

class TestFrontierOperatingPoints:
    def test_frontier_spans_batch_sizes(self, setup):
        _, resources, db, net = setup
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        res = eng.frontier(Query(replicas={"device": 2}))
        batches = {c.batch_size for c in res.configs}
        assert 1 in batches                     # latency-at-batch-1 end
        assert len(batches) > 1                 # ...through batched points
        lats = [c.latency_s for c in res.configs]
        assert lats == sorted(lats)

    def test_frontier_default_sweeps_measured_batches_only(self, setup):
        _, resources, db, net = setup
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        res = eng.frontier(Query())
        assert {c.batch_size for c in res.configs} <= \
            set(db.measured_batches())

    def test_acceptance_batched_replicated_beats_batch1(self, setup):
        """Acceptance: the frontier contains a replicated or batched
        operating point whose predicted throughput beats the best batch-1
        single-replica partition, and the simulation confirms the
        prediction within 15%."""
        _, resources, db, net = setup
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        base = eng.run(Query(top_n=1, objective=THROUGHPUT)).best
        assert base.batch_size == 1 and set(base.replicas) <= {1}
        res = eng.frontier(Query(replicas={"device": 2, "edge1": 2,
                                           "cloud": 2}))
        top = max(res.configs, key=lambda c: c.throughput_rps)
        assert top.batch_size > 1 or any(r > 1 for r in top.replicas)
        assert top.throughput_rps > base.throughput_rps
        sim = simulate_pipeline_throughput(top, n_requests=512)
        assert sim == pytest.approx(top.throughput_rps, rel=0.15)

    def test_run_at_operating_point(self, setup):
        _, resources, db, net = setup
        eng = QueryEngine(db, resources, net, source="device",
                          input_bytes=150e3)
        res = eng.run(Query(top_n=1, objective=THROUGHPUT, batch_size=16,
                            replicas={"device": 2}))
        best = res.best
        assert best.batch_size == 16
        base = eng.run(Query(top_n=1, objective=THROUGHPUT)).best
        assert best.throughput_rps >= base.throughput_rps


# ---------------------------------------------------------------------------
# replica-aware pipeline simulation
# ---------------------------------------------------------------------------

class TestSimulation:
    def test_rejects_too_few_requests(self, setup):
        _, resources, db, net = setup
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3)
        cfg = cost.evaluate([Segment("device", 0, db.n_blocks - 1)])
        for n in (0, 1, -3):
            with pytest.raises(ValueError, match="n_requests"):
                simulate_pipeline_throughput(cfg, n_requests=n)

    def test_rejects_stageless_config(self):
        from repro.core.partition import PartitionConfig
        bare = PartitionConfig(model="x", segments=(), latency_s=1.0,
                               compute_s={}, comm_s=0.0, transfer_bytes=0.0)
        with pytest.raises(ValueError, match="stages"):
            simulate_pipeline_throughput(bare)

    def test_replicated_minimal_requests_finite(self, setup):
        """Regression: with replicas > 1 and very few requests, every
        in-flight batch could finish simultaneously on distinct servers and
        the measured span collapsed to zero -> inf; the simulator must run
        the pipeline long enough to reach a steady state instead."""
        _, resources, db, net = setup
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3,
                         replica_budget={"device": 2})
        cfg = cost.evaluate([Segment("device", 0, db.n_blocks - 1)])
        sim = simulate_pipeline_throughput(cfg, n_requests=2)
        assert np.isfinite(sim)
        assert sim == pytest.approx(cfg.throughput_rps, rel=0.02)

    def test_replicated_stage_rate_matches_prediction(self, setup):
        _, resources, db, net = setup
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3,
                         replica_budget={"device": 3})
        cfg = cost.evaluate([Segment("device", 0, db.n_blocks - 1)])
        sim = simulate_pipeline_throughput(cfg, n_requests=512)
        assert sim == pytest.approx(cfg.throughput_rps, rel=0.02)
        # three device replicas triple the native rate
        single = CostModel(db=db, resources=resources, network=net,
                           source="device", input_bytes=150e3).evaluate(
            [Segment("device", 0, db.n_blocks - 1)])
        assert cfg.throughput_rps == pytest.approx(
            3 * single.throughput_rps)

    def test_batched_sim_counts_requests_not_batches(self, setup):
        _, resources, db, net = setup
        cost = CostModel(db=db, resources=resources, network=net,
                         source="device", input_bytes=150e3, batch_size=16)
        B = db.n_blocks
        cfg = cost.evaluate([Segment("device", 0, 3),
                             Segment("cloud", 4, B - 1)])
        sim = simulate_pipeline_throughput(cfg, n_requests=1024)
        assert sim == pytest.approx(cfg.throughput_rps, rel=0.02)


# ---------------------------------------------------------------------------
# planner: re-benchmarking must invalidate cached engines
# ---------------------------------------------------------------------------

class TestEngineInvalidation:
    def test_rebenchmark_invalidates_cached_engines(self, setup):
        """Regression: Scission.benchmark()/load()/restore() replaced the
        model DB but kept cached QueryEngines holding the old one, so a
        re-benchmark (e.g. adding batch profiles) silently priced later
        queries from stale measurements."""
        graph, resources, _, net = setup
        s = Scission(resources=list(resources), network=net, source="device",
                     provider=AnalyticProvider(), runs=1)
        s.benchmark(graph)                        # batch-1 only
        assert s.frontier(graph.name).configs     # builds + caches an engine
        with pytest.raises(ValueError, match="largest measured batch"):
            s.query(graph.name, Query(batch_size=4))
        s.benchmark(graph, batch_sizes=(1, 4))    # upgrade the profile
        best = s.query(graph.name, Query(top_n=1, objective=THROUGHPUT,
                                         batch_size=4)).best
        assert best.batch_size == 4               # new engine, new DB


# ---------------------------------------------------------------------------
# elastic re-planning preserves the operating point
# ---------------------------------------------------------------------------

class TestElasticOperatingPoint:
    def _scission(self, setup):
        graph, resources, db, net = setup
        s = Scission(resources=list(resources), network=net, source="device",
                     provider=AnalyticProvider(), runs=1)
        s.load(db)
        return graph, s

    def test_replan_preserves_batch_and_replicas(self, setup):
        graph, s = self._scission(setup)
        budget = {"device": 2, "edge1": 2}
        ctl = ElasticController(
            s, graph.name, query=Query(top_n=1, objective=THROUGHPUT,
                                       batch_size=4, replicas=budget),
            graph=graph)
        assert ctl.current.batch_size == 4
        ev = ctl.on_resource_lost("edge1")
        assert ev.config.batch_size == 4          # operating point survives
        assert all(s.resource != "edge1" for s in ev.config.segments)
        # the budget (including the lost resource's entry) is untouched, so
        # a rejoin restores the full operating point
        assert ctl.query.replicas == {"device": 2, "edge1": 2}
        assert budget == {"device": 2, "edge1": 2}   # caller's dict intact
        batch, reps = ev.operating_point
        assert batch == 4 and len(reps) == len(ev.config.segments)
        ev2 = ctl.on_resource_joined(
            Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.0))
        assert ev2.config.batch_size == 4
        assert ev2.config.bottleneck_s == pytest.approx(
            ctl.history[0].config.bottleneck_s)

    def test_join_measures_existing_batches(self, setup):
        graph, s = self._scission(setup)
        ctl = ElasticController(
            s, graph.name, query=Query(top_n=1, batch_size=16), graph=graph)
        newcomer = Resource("edge9", "edge", EDGE_BOX_1, speed_factor=2.0)
        ev = ctl.on_resource_joined(newcomer)
        db = ctl.scission._dbs[graph.name]
        rec = db.records["edge9"][0]
        assert set(rec.batch_profile) == {1, 4, 16}
        assert ev.config.batch_size == 16
