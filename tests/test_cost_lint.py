"""scission-lint v2: cost-model soundness (SCN4xx), jaxpr dataflow lint
(SCN5xx), TPU tiling analysis (SCN204-207), and their wiring.

Each new diagnostic code has a minimal triggering fixture; clean inputs
must produce zero findings (the soundness direction).  The tiling pass is
additionally exercised through the autotuner (misaligned candidates are
pruned before measurement, winners unchanged) and the serving registry
(``adopt_tuned_params`` changes the actual chunking of the model-zoo
attention/SSD paths, observable in their jaxprs).
"""

import json
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.cost_lint import (lint_cost, lint_cost_db,
                                      lint_cost_model, lint_network)
from repro.analysis.diagnostics import ERROR, WARNING
from repro.analysis.jaxpr_lint import lint_block, lint_blocks
from repro.analysis.tiling import (analyze_tiling, lint_tiling, min_tile,
                                   misaligned_candidates)
from repro.core import (Link, NetworkModel, Query, QueryEngine, Resource,
                        linear_graph)
from repro.core.bench import (AnalyticProvider, BenchmarkDB, BlockBenchmark,
                              benchmark_model)
from repro.core.graph import LayerNode, fuse_blocks
from repro.core.partition import CostModel
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.kernels.substrate import (DEFAULT_PARAMS, KernelAutotuner,
                                     adopt_tuned_params, clear_tuned_params,
                                     kernel_for_params, serving_param)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # container has no hypothesis
    HAVE_HYPOTHESIS = False

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _codes(diags):
    return {d.code for d in diags}


def _db(n_blocks=3, resources=("edge", "cloud"), batches=(1, 4)):
    """A clean v2 DB: positive times, monotone profiles, full coverage."""
    db = BenchmarkDB(model="lint", n_blocks=n_blocks)
    for k, r in enumerate(resources):
        recs = []
        for i in range(n_blocks):
            t = 0.001 * (i + 1) * (k + 1)
            profile = {b: (t * b * (1.0 + 0.1 * b), 1024 * (i + 1) * b)
                       for b in batches}
            profile[1] = (t, 1024 * (i + 1))
            recs.append(BlockBenchmark(
                block=i, resource=r, mean_time_s=t, std_time_s=0.0,
                output_bytes=1024 * (i + 1), runs=1,
                batch_profile=profile))
        db.records[r] = recs
    return db


def _fleet():
    return [Resource("edge", "edge", EDGE_BOX_1),
            Resource("cloud", "cloud", CLOUD_VM)]


# ---------------------------------------------------------------------------
# SCN401-403: BenchmarkDB soundness
# ---------------------------------------------------------------------------

class TestCostDbLint:
    def test_clean_db_zero_findings(self):
        assert lint_cost_db(_db()) == []

    def test_scn401_negative_time(self):
        db = _db()
        db.records["edge"][0].mean_time_s = -0.5
        diags = lint_cost_db(db)
        (d,) = [x for x in diags if x.code == "SCN401"]
        assert d.severity == ERROR and d.subject == "edge/block0"
        assert "dominance" in d.message      # names the voided guarantee

    def test_scn401_nan_bytes_and_profile(self):
        db = _db()
        db.records["cloud"][1].output_bytes = float("nan")
        db.records["cloud"][2].batch_profile[4] = (float("inf"), 4096)
        diags = [d for d in lint_cost_db(db) if d.code == "SCN401"]
        assert {d.subject for d in diags} == {"cloud/block1", "cloud/block2"}

    def test_scn402_non_monotone_profile(self):
        db = _db()
        db.records["edge"][1].batch_profile[4] = (0.0001, 8192)
        diags = [d for d in lint_cost_db(db) if d.code == "SCN402"]
        assert len(diags) == 1 and diags[0].severity == WARNING
        assert diags[0].subject == "edge/block1"

    def test_scn402_skipped_when_non_finite(self):
        # a NaN profile point is SCN401's finding, not a bogus SCN402
        db = _db()
        db.records["edge"][0].batch_profile[4] = (float("nan"), 8192)
        codes = _codes(lint_cost_db(db))
        assert "SCN401" in codes and "SCN402" not in codes

    def test_scn403_batch_coverage_gap(self):
        db = _db()
        for rec in db.records["edge"]:
            rec.batch_profile.pop(4)
        diags = [d for d in lint_cost_db(db) if d.code == "SCN403"]
        assert len(diags) == 1 and diags[0].subject == "edge"
        assert "[4]" in diags[0].message

    def test_resources_filter_ignores_stale_records(self):
        db = _db()
        db.records["gone"] = [BlockBenchmark(
            block=0, resource="gone", mean_time_s=-1.0, std_time_s=0.0,
            output_bytes=1, runs=1)]
        assert lint_cost_db(db, resources=["edge", "cloud"]) == []

    def test_seeded_random_monotone_dbs_are_clean(self):
        # soundness property: any DB with positive, batch-monotone
        # profiles and full coverage yields zero findings
        rng = np.random.default_rng(0)
        for _ in range(25):
            db = BenchmarkDB(model="rnd", n_blocks=3)
            batches = (1, 2, 8)
            for r in ("a", "b", "c"):
                recs = []
                for i in range(3):
                    t = float(rng.uniform(1e-5, 1e-2))
                    prof, cur = {}, t
                    for b in batches:
                        cur = cur * b * float(rng.uniform(1.0, 1.5)) \
                            if b > 1 else t
                        prof[b] = (cur, 128 * b)
                    recs.append(BlockBenchmark(
                        block=i, resource=r, mean_time_s=t, std_time_s=0.0,
                        output_bytes=128, runs=1, batch_profile=prof))
                db.records[r] = recs
            assert lint_cost_db(db) == []

    if HAVE_HYPOTHESIS:
        @settings(max_examples=50, deadline=None)
        @given(st.lists(st.floats(1e-6, 1.0), min_size=1, max_size=4),
               st.lists(st.floats(1.0, 2.0), min_size=3, max_size=3))
        def test_hypothesis_monotone_profiles_are_clean(self, times, growth):
            db = BenchmarkDB(model="hyp", n_blocks=len(times))
            recs = []
            for i, t in enumerate(times):
                prof, cur, b = {1: (t, 64)}, t, 1
                for g in growth:
                    b *= 2
                    cur = cur * 2 * g
                    prof[b] = (cur, 64 * b)
                recs.append(BlockBenchmark(
                    block=i, resource="r", mean_time_s=t, std_time_s=0.0,
                    output_bytes=64, runs=1, batch_profile=prof))
            db.records["r"] = recs
            assert lint_cost_db(db) == []


# ---------------------------------------------------------------------------
# SCN404-406: NetworkModel soundness
# ---------------------------------------------------------------------------

class TestNetworkLint:
    def test_clean_network(self):
        net = NetworkModel(default=Link("wired", 0.005, 1e8))
        net.connect("edge", "cloud", Link("wan", 0.02, 1e7))
        assert lint_network(net) == []

    def test_scn404_negative_latency_default(self):
        net = NetworkModel(default=Link("bad", -0.01, 1e8))
        diags = [d for d in lint_network(net) if d.code == "SCN404"]
        assert len(diags) == 1 and diags[0].severity == ERROR
        assert diags[0].subject == "default"

    def test_scn404_nonpositive_bandwidth_link(self):
        net = NetworkModel(default=Link("wired", 0.005, 1e8))
        net.connect("a", "b", Link("dead", 0.01, 0.0), symmetric=False)
        diags = [d for d in lint_network(net) if d.code == "SCN404"]
        assert len(diags) == 1 and diags[0].subject == "a->b"

    def test_infinite_bandwidth_is_fine(self):
        net = NetworkModel(default=Link("instant", 0.0, float("inf")))
        assert lint_network(net) == []

    def test_scn405_asymmetric_explicit_pair(self):
        net = NetworkModel(default=Link("wired", 0.005, 1e8))
        net.connect("a", "b", Link("up", 0.01, 1e6), symmetric=False)
        net.connect("b", "a", Link("down", 0.01, 1e8), symmetric=False)
        diags = [d for d in lint_network(net) if d.code == "SCN405"]
        assert len(diags) == 1 and diags[0].subject == "a<->b"

    def test_symmetric_pair_is_clean(self):
        net = NetworkModel(default=Link("wired", 0.005, 1e8))
        net.connect("a", "b", Link("wan", 0.02, 1e6), symmetric=True)
        assert [d for d in lint_network(net) if d.code == "SCN405"] == []

    def test_scn406_costly_self_link(self):
        net = NetworkModel(default=Link("wired", 0.005, 1e8))
        net.connect("a", "a", Link("slow-self", 1.0, 1e3), symmetric=False)
        diags = [d for d in lint_network(net) if d.code == "SCN406"]
        assert len(diags) == 1 and diags[0].subject == "a->a"


# ---------------------------------------------------------------------------
# SCN407: cost-model composition
# ---------------------------------------------------------------------------

def _cost(db=None, batch=1):
    return CostModel(db=db or _db(), resources=_fleet(),
                     network=NetworkModel(default=Link("wired", 0.005, 1e8)),
                     source="edge", input_bytes=4096.0, batch_size=batch)


class TestCostModelLint:
    def test_clean_cost_model(self):
        assert lint_cost_model(_cost()) == []

    def test_clean_cost_model_batched(self):
        assert lint_cost_model(_cost(batch=4)) == []

    def test_scn407_broken_segment_time(self):
        class Broken(CostModel):
            def segment_time(self, r, s, e):
                return super().segment_time(r, s, e) * 1.5

        broken = Broken(db=_db(), resources=_fleet(),
                        network=NetworkModel(
                            default=Link("wired", 0.005, 1e8)),
                        source="edge", input_bytes=4096.0, batch_size=1)
        diags = [d for d in lint_cost_model(broken) if d.code == "SCN407"]
        assert diags and all(d.severity == ERROR for d in diags)
        assert any("additive" in d.message for d in diags)

    def test_skips_resources_scn401_owns(self):
        db = _db()
        for rec in db.records["edge"]:
            rec.mean_time_s = float("nan")
        # the composition pass must not crash or double-report; the full
        # pass still carries the SCN401s
        assert lint_cost_model(_cost(db)) == []
        codes = _codes(lint_cost(db, cost=_cost(db)))
        assert "SCN401" in codes and "SCN407" not in codes


# ---------------------------------------------------------------------------
# SCN5xx: jaxpr dataflow lint
# ---------------------------------------------------------------------------

def _dense_node(name, d=8, apply=None):
    w = jnp.eye(d) * 0.5
    return LayerNode(name=name, kind="dense",
                     apply=apply or (lambda x, w=w: jnp.tanh(x @ w)),
                     flops=2.0 * d * d)


def _graph_of(*nodes, d=8):
    return linear_graph("jx", jax.ShapeDtypeStruct((1, 4, d), jnp.float32),
                        list(nodes))


class TestJaxprLint:
    def test_clean_graph_zero_findings(self):
        g = _graph_of(_dense_node("a"), _dense_node("b"))
        assert lint_blocks(fuse_blocks(g)) == []

    def test_scn501_float64_leakage(self):
        with jax.experimental.enable_x64():
            g = _graph_of(_dense_node(
                "f64", apply=lambda x: (x.astype(jnp.float64) * 2.0)
                .astype(jnp.float32)))
            diags = lint_blocks(fuse_blocks(g))
        d = next(x for x in diags if x.code == "SCN501")
        assert d.severity == WARNING and "float64" in d.message

    def test_scn502_db_byte_disagreement(self):
        g = _graph_of(_dense_node("a"))
        blocks = list(fuse_blocks(g))
        db = benchmark_model(g, [Resource("cloud", "cloud", CLOUD_VM)],
                             AnalyticProvider(), runs=1, blocks=blocks)
        assert lint_block(blocks[0], db=db) == []
        db.records["cloud"][0].output_bytes += 64       # tamper
        diags = lint_block(blocks[0], db=db)
        d = next(x for x in diags if x.code == "SCN502")
        assert "BenchmarkDB.output_bytes" in d.message

    def test_scn503_host_callback(self):
        def apply(x):
            jax.debug.callback(lambda v: None, x.sum())
            return x * 2.0

        g = _graph_of(_dense_node("cb", apply=apply))
        diags = lint_blocks(fuse_blocks(g))
        d = next(x for x in diags if x.code == "SCN503")
        assert d.severity == ERROR and "debug_callback" in d.message

    def test_scn503_untraceable_block(self):
        # a node whose apply was swapped post-trace for a host-concretizing
        # one: graph.trace() never saw it, only the block lint can
        g = _graph_of(_dense_node("a"))
        g.nodes[1].apply = lambda x: jnp.asarray(np.asarray(x) + 1.0)
        diags = lint_blocks(fuse_blocks(g))
        assert [d.code for d in diags] == ["SCN503"]
        assert "abstract tracing" in diags[0].message

    def test_scn504_subf32_accumulation_on_kernel_block(self):
        w = jnp.eye(8, dtype=jnp.bfloat16)

        def apply(x):
            y = x.astype(jnp.bfloat16) @ w              # bf16 dot_general
            return y.astype(jnp.float32)

        node = LayerNode(name="k", kind="attention", apply=apply,
                         kernel="flash_attention")
        g = _graph_of(node)
        diags = lint_blocks(fuse_blocks(g))
        assert "SCN504" in _codes(diags)
        # same dataflow without the kernel marker is plain mixed precision
        g2 = _graph_of(LayerNode(name="nk", kind="dense", apply=apply))
        assert "SCN504" not in _codes(lint_blocks(fuse_blocks(g2)))

    def test_kernel_demo_graph_is_clean(self):
        from repro.kernels.ops import flash_attention_node, ssd_scan_node
        g = linear_graph(
            "demo", jax.ShapeDtypeStruct((1, 128, 2, 32), jnp.float32),
            [flash_attention_node("attn", interpret=True),
             ssd_scan_node("ssd", state_dim=16, interpret=True)])
        assert lint_blocks(fuse_blocks(g)) == []


# ---------------------------------------------------------------------------
# SCN204-207: tiling analysis + autotuner pruning
# ---------------------------------------------------------------------------

_F32ARG = (jax.ShapeDtypeStruct((1, 256, 2, 64), jnp.float32),)
_BF16ARG = (jax.ShapeDtypeStruct((1, 256, 2, 64), jnp.bfloat16),)


class TestTiling:
    def test_min_tile_table(self):
        assert min_tile(jnp.float32) == (8, 128)
        assert min_tile(jnp.bfloat16) == (16, 128)
        assert min_tile(jnp.int8) == (32, 128)
        assert min_tile(jnp.float64) == (8, 128)        # fallback

    def test_aligned_candidate(self):
        ta = analyze_tiling("flash_attention",
                            {"block_q": 128, "block_k": 128}, _F32ARG, {})
        assert ta.is_aligned and ta.grid_waste == {}
        assert ta.lane_padded                           # hd=64 < 128 lanes

    def test_misaligned_and_waste(self):
        ta = analyze_tiling("flash_attention",
                            {"block_q": 100, "block_k": 64}, _F32ARG, {})
        assert not ta.is_aligned and "q" in ta.misaligned
        assert ta.misaligned["q"] == (100, 8)
        # 256 rounds up to 300 under block 100: ~15% padded away
        assert math.isclose(ta.waste_fraction, 1 - 256 / 300, abs_tol=1e-9)

    def test_bf16_tightens_sublane(self):
        params = {"block_q": 8, "block_k": 8}
        assert analyze_tiling("flash_attention", params, _F32ARG,
                              {}).is_aligned
        assert not analyze_tiling("flash_attention", params, _BF16ARG,
                                  {}).is_aligned

    def test_lint_tiling_scn204_205_207(self):
        cands = [{"block_q": 128, "block_k": 128},
                 {"block_q": 100, "block_k": 64}]
        kept, flagged, diags = lint_tiling("flash_attention", cands,
                                           _F32ARG, subject="flash")
        assert kept == [cands[0]] and len(flagged) == 1
        codes = [d.code for d in diags]
        assert "SCN204" in codes and "SCN205" in codes \
            and codes.count("SCN207") == 1
        scn204 = next(d for d in diags if d.code == "SCN204")
        assert scn204.severity == WARNING

    def test_scn206_all_misaligned(self):
        cands = [{"block_q": 100, "block_k": 100},
                 {"block_q": 12, "block_k": 12}]
        kept, flagged, diags = lint_tiling("flash_attention", cands,
                                           _F32ARG)
        assert kept == [] and len(flagged) == 2
        d = next(x for x in diags if x.code == "SCN206")
        assert d.severity == ERROR

    def test_unknown_kernel_flags_nothing(self):
        assert misaligned_candidates("not_a_kernel", [{"x": 3}],
                                     _F32ARG) == {}
        kept, flagged, diags = lint_tiling("not_a_kernel", [{"x": 3}],
                                           _F32ARG)
        assert kept == [{"x": 3}] and not flagged and not diags

    def test_default_candidate_grids_are_aligned(self):
        # default-on pruning must never touch the committed sweeps (at the
        # representative shapes the CLI kernels/tiling targets use)
        from repro.analysis.cli import _KERNEL_SHAPES
        from repro.kernels.substrate import DEFAULT_CANDIDATES
        for kernel, cands in sorted(DEFAULT_CANDIDATES.items()):
            args, options = _KERNEL_SHAPES[kernel]
            assert misaligned_candidates(kernel, cands, args,
                                         options) == {}


class TestAutotunerTilePruning:
    def _tuner(self, candidates, tile_check):
        seen = []

        def factory(params):
            def fn(x):
                return x
            fn.params = dict(params)
            return fn

        def measure(fn, args):
            seen.append(dict(fn.params))
            return float(sum(fn.params.values()))

        tuner = KernelAutotuner(candidates={"flash_attention": candidates},
                                measure=measure, tile_check=tile_check)
        return tuner, factory, seen

    def test_misaligned_pruned_before_measurement(self):
        cands = [{"block_q": 128, "block_k": 128},
                 {"block_q": 64, "block_k": 100}]
        tuner, factory, seen = self._tuner(cands, tile_check=True)
        rec = tuner.tune("flash_attention", factory, _F32ARG,
                         resource="host")
        assert len(rec.tile_pruned) == 1
        assert {"block_q": 64, "block_k": 100} not in seen
        assert rec.params == {"block_q": 128, "block_k": 128}

    def test_tile_check_off_measures_everything(self):
        cands = [{"block_q": 128, "block_k": 128},
                 {"block_q": 64, "block_k": 100}]
        tuner, factory, seen = self._tuner(cands, tile_check=False)
        rec = tuner.tune("flash_attention", factory, _F32ARG,
                         resource="host")
        assert rec.tile_pruned == {} and len(seen) == 2

    def test_never_empties_the_sweep(self):
        # when every candidate is misaligned, measure them anyway
        cands = [{"block_q": 100, "block_k": 100}]
        tuner, factory, seen = self._tuner(cands, tile_check=True)
        rec = tuner.tune("flash_attention", factory, _F32ARG,
                         resource="host", defaults=cands[0])
        assert rec.params == cands[0] and rec.tile_pruned == {}
        assert len(seen) == 1

    def test_tune_record_json_roundtrip(self):
        cands = [{"block_q": 128, "block_k": 128},
                 {"block_q": 64, "block_k": 100}]
        tuner, factory, _ = self._tuner(cands, tile_check=True)
        tuner.tune("flash_attention", factory, _F32ARG, resource="host")
        back = KernelAutotuner.from_json(tuner.to_json())
        rec = next(iter(back.records.values()))
        assert len(rec.tile_pruned) == 1
        key = json.dumps({"block_q": 64, "block_k": 100}, sort_keys=True)
        assert "sublane-misaligned" in rec.tile_pruned[key]

    def test_v1_tune_record_payload_still_loads(self):
        # persisted records predating tile_pruned must keep loading
        tuner, factory, _ = self._tuner([{"block_q": 64, "block_k": 64}],
                                        tile_check=False)
        tuner.tune("flash_attention", factory, _F32ARG, resource="host")
        payload = json.loads(tuner.to_json())
        for rec in payload:
            rec.pop("tile_pruned")
        back = KernelAutotuner.from_json(json.dumps(payload))
        assert next(iter(back.records.values())).tile_pruned == {}


# ---------------------------------------------------------------------------
# serving-path adoption of tuned params
# ---------------------------------------------------------------------------

class TestServingParams:
    def _tuned_db(self, flash=None, ssd=None):
        db = _db(n_blocks=2, resources=("cloud",), batches=(1,))
        db.records["cloud"][0].tuned_params = {
            "attn": flash or {"block_q": 64, "block_k": 64}}
        db.records["cloud"][1].tuned_params = {
            "ssd": ssd or {"chunk": 32}}
        return db

    def test_kernel_for_params(self):
        assert kernel_for_params({"block_q": 1, "block_k": 1}) \
            == "flash_attention"
        assert kernel_for_params({"chunk": 1}) == "ssd_scan"
        assert kernel_for_params({"block_k": 1}) == "decode_attention"
        assert kernel_for_params({"zap": 1}) is None

    def test_adopt_and_serve(self):
        try:
            adopted = adopt_tuned_params(self._tuned_db())
            assert adopted["flash_attention"] == {"block_q": 64,
                                                  "block_k": 64}
            assert serving_param("flash_attention", "block_q", 512) == 64
            assert serving_param("ssd_scan", "chunk", 128) == 32
        finally:
            clear_tuned_params()
        assert serving_param("flash_attention", "block_q", 512) == 512

    def test_misaligned_tuned_params_rejected(self):
        try:
            adopted = adopt_tuned_params(
                self._tuned_db(flash={"block_q": 60, "block_k": 64}))
            assert "flash_attention" not in adopted
            assert serving_param("flash_attention", "block_q", 512) == 512
        finally:
            clear_tuned_params()

    def test_sdpa_chunks_at_adopted_block_q(self):
        from repro.models.layers import sdpa
        S, H, hd = 128, 2, 16
        q = jnp.zeros((1, S, H, hd))
        pos = jnp.arange(S)

        def scan_lengths():
            # a fresh closure per trace: jax caches traces on fn identity,
            # which would mask the registry change
            jaxpr = jax.make_jaxpr(
                lambda q: sdpa(q, q, q, q_pos=pos, k_pos=pos))(q)
            return [int(e.params["length"]) for e in jaxpr.eqns
                    if e.primitive.name == "scan"]

        assert scan_lengths() == []          # fallback q_chunk=512 >= S
        try:
            adopt_tuned_params(self._tuned_db())           # block_q=64
            assert scan_lengths() == [S // 64]
        finally:
            clear_tuned_params()
        assert scan_lengths() == []

    def test_ssd_chunks_at_adopted_chunk(self):
        from repro.models.ssm import ssd
        S, H, P, N = 128, 2, 16, 8
        x = jnp.zeros((1, S, H, P))
        log_a = jnp.zeros((1, S, H))
        b = jnp.zeros((1, S, 1, N))

        def nc():
            jaxpr = jax.make_jaxpr(lambda x: ssd(x, log_a, b, b)[0])(x)
            return [int(e.params["length"]) for e in jaxpr.eqns
                    if e.primitive.name == "scan"]

        assert nc() == [S // 128]            # fallback chunk=128
        try:
            adopt_tuned_params(self._tuned_db())           # chunk=32
            assert nc() == [S // 32]
        finally:
            clear_tuned_params()


# ---------------------------------------------------------------------------
# engine wiring + CLI
# ---------------------------------------------------------------------------

class TestEngineWiring:
    def _engine(self, db):
        net = NetworkModel(default=Link("wired", 0.005, 1e8))
        return QueryEngine(db, _fleet(), net, source="edge",
                           input_bytes=4096.0)

    def test_clean_engine_clean_result(self):
        r = self._engine(_db()).run(Query())
        assert r.configs and r.diagnostics == []

    def test_corrupted_db_surfaces_on_results(self):
        db = _db()
        db.records["cloud"][1].batch_profile[4] = (1e-7, 8192)  # SCN402
        r = self._engine(db).run(Query())
        assert r.configs
        d = next(x for x in r.diagnostics if x.code == "SCN402")
        assert d.subject == "cloud/block1"

    def test_error_findings_attach_too(self):
        db = _db()
        db.records["edge"][2].output_bytes = -5
        r = self._engine(db).run(Query())
        assert "SCN401" in {d.code for d in r.diagnostics}


class TestCli:
    def _main(self, *argv):
        from repro.analysis.cli import main
        return main(list(argv))

    def test_clean_db_passes_strict(self, capsys):
        assert self._main("--strict", "cost",
                          str(ROOT / "examples/dbs/edge_cloud_db.json")) == 0
        assert "clean" in capsys.readouterr().out

    def test_corrupted_db_fails_strict(self, capsys):
        path = str(ROOT / "examples/dbs/corrupted_db.json")
        assert self._main("--strict", "cost", path) == 1
        out = capsys.readouterr().out
        assert "SCN401" in out and "SCN402" in out and "SCN403" in out
        # non-strict reports but exits 0
        assert self._main("cost", path) == 0

    def test_allow_waives_codes(self):
        path = str(ROOT / "examples/dbs/corrupted_db.json")
        assert self._main("--strict", "--allow", "SCN401", "--allow",
                          "SCN402", "--allow", "SCN403", "cost", path) == 0
        # waiving only the warnings still fails on the error
        assert self._main("--strict", "--allow", "SCN402", "--allow",
                          "SCN403", "cost", path) == 1

    def test_tiling_target_is_strict_clean(self):
        assert self._main("--strict", "tiling") == 0

    def test_cost_keyword_requires_path(self):
        with pytest.raises(SystemExit):
            self._main("cost")
