"""Substrate tests: optimizer, checkpointing, data pipeline, fault
tolerance, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.data import DataConfig, SyntheticLM, make_iterator
from repro.optim import (AdamWConfig, apply_updates, clip_by_global_norm,
                         cosine_with_warmup, global_norm, init_state,
                         quantize, dequantize)
from repro.runtime.ft import (HeartbeatRegistry, ShardAssignment,
                              StragglerDetector, TrainSupervisor)


class TestAdamW:
    def _params(self):
        k = jax.random.PRNGKey(0)
        return {"w": jax.random.normal(k, (8, 8), jnp.float32),
                "b": jnp.zeros((8,), jnp.float32)}

    def test_reduces_quadratic_loss(self):
        params = self._params()
        state = init_state(params)
        cfg = AdamWConfig(lr=5e-2, weight_decay=0.0)
        target = jax.random.normal(jax.random.PRNGKey(1), (8, 8))

        def loss(p):
            return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)

        l0 = float(loss(params))
        step = jax.jit(lambda p, s: apply_updates(cfg, p, jax.grad(loss)(p),
                                                  s)[:2])
        for _ in range(100):
            params, state = step(params, state)
        assert float(loss(params)) < l0 * 0.1
        assert int(state["step"]) == 100

    def test_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
        assert float(norm) == pytest.approx(200.0)

    def test_bf16_params_fp32_moments(self):
        params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        state = init_state(params)
        assert state["mu"]["w"].dtype == jnp.float32
        grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        new_p, new_s, _ = apply_updates(AdamWConfig(lr=1e-2), params, grads,
                                        state)
        assert new_p["w"].dtype == jnp.bfloat16

    def test_schedule(self):
        s = cosine_with_warmup(1.0, 10, 100)
        assert float(s(jnp.int32(0))) == 0.0
        assert float(s(jnp.int32(10))) == pytest.approx(1.0, abs=1e-3)
        assert float(s(jnp.int32(100))) == pytest.approx(0.1, abs=1e-3)


class TestCompression:
    def test_quant_roundtrip_small_error(self):
        g = jax.random.normal(jax.random.PRNGKey(2), (128,))
        q, scale, resid = quantize(g)
        err = np.abs(np.asarray(dequantize(q, scale) + resid - g))
        assert err.max() < 1e-6      # residual exactly captures the error

    def test_error_feedback_reduces_bias(self):
        g = jnp.full((16,), 0.003)
        resid = None
        total = 0.0
        for _ in range(100):
            q, scale, resid = quantize(g, resid)
            total += float(dequantize(q, scale).sum())
        # with error feedback the long-run mean matches the true gradient
        assert total / 100 == pytest.approx(float(g.sum()), rel=0.05)


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        tree = {"a": jnp.ones((3, 3), jnp.bfloat16),
                "b": {"c": jnp.arange(5, dtype=jnp.int32)}}
        p = str(tmp_path / "x.ckpt.zst")
        save(p, tree, step=7, meta={"note": "hi"})
        got, step, meta = restore(p, tree)
        assert step == 7 and meta["note"] == "hi"
        assert got["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                      np.arange(5))

    def test_manager_retention_and_resume(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_writes=False)
        tree = {"w": jnp.zeros((2,))}
        for s in (10, 20, 30):
            mgr.save(s, jax.tree.map(lambda x: x + s, tree))
        assert mgr.steps() == [20, 30]
        got, step, _ = mgr.restore_latest(tree)
        assert step == 30
        np.testing.assert_allclose(np.asarray(got["w"]), 30.0)

    def test_async_save_waits(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=1, async_writes=True)
        mgr.save(1, {"w": jnp.ones((64, 64))})
        mgr.wait()
        assert mgr.steps() == [1]


class TestData:
    def test_deterministic_across_hosts(self):
        cfg = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=3)
        ds = SyntheticLM(cfg)
        full = ds.global_batch_at(5)
        shards = [ds.host_batch_at(5, h, 4) for h in range(4)]
        # host sharding partitions the batch deterministically (each host is
        # independent of host count only through its (step, host) seed)
        assert all(s["tokens"].shape == (2, 16) for s in shards)
        assert full["tokens"].shape == (8, 16)
        # restartability: same step -> same data
        np.testing.assert_array_equal(ds.global_batch_at(5)["tokens"],
                                      full["tokens"])

    def test_learnable_structure(self):
        cfg = DataConfig(vocab=64, seq_len=32, global_batch=4, seed=0)
        ds = SyntheticLM(cfg)
        b = ds.global_batch_at(0)
        perm = ds._perm
        # ~90% of labels follow the permutation rule
        match = (perm[b["tokens"]] == b["labels"]).mean()
        assert match > 0.8

    def test_iterator_resume(self):
        cfg = DataConfig(vocab=64, seq_len=8, global_batch=2)
        it1 = make_iterator(cfg, start_step=3)
        step, batch = next(it1)
        assert step == 3
        it2 = make_iterator(cfg, start_step=3)
        _, batch2 = next(it2)
        np.testing.assert_array_equal(batch["tokens"], batch2["tokens"])


class TestFaultTolerance:
    def test_straggler_detection(self):
        det = StragglerDetector(window=8, k=3.0)
        for step in range(8):
            for h in range(8):
                det.record(h, 1.0 + 0.01 * h)
            det.record(8, 5.0)       # host 8 is 5x slower
        assert det.stragglers() == [8]

    def test_heartbeat_death(self):
        t = [0.0]
        reg = HeartbeatRegistry(timeout_s=10.0, now=lambda: t[0])
        reg.beat("a")
        reg.beat("b")
        t[0] = 5.0
        reg.beat("a")
        t[0] = 12.0
        assert reg.dead() == ["b"]

    def test_shard_rebalance_on_host_loss(self):
        sa = ShardAssignment(n_shards=16, hosts=list(range(4)))
        assert sum(len(v) for v in sa.assignment.values()) == 16
        sa.drop_host(2)
        assert 2 not in sa.assignment
        assert sum(len(v) for v in sa.assignment.values()) == 16
        assert max(len(v) for v in sa.assignment.values()) - \
            min(len(v) for v in sa.assignment.values()) <= 1

    def test_supervisor_checkpoint_resume(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_writes=False)
        sup = TrainSupervisor(mgr, ckpt_every=5)
        state = {"w": jnp.zeros((2,))}
        st, start = sup.resume_or_init(lambda: state, like=None)
        assert start == 0
        for step in range(1, 11):
            state = {"w": state["w"] + 1}
            sup.after_step(step, state, wall_s=0.1)
        mgr.wait()
        got, step = TrainSupervisor(mgr, ckpt_every=5).resume_or_init(
            lambda: {"w": jnp.zeros((2,))}, like=state)
        assert step == 10
        np.testing.assert_allclose(np.asarray(got["w"]), 10.0)
