"""Graph IR: partition points, block fusion, branching semantics (§II-A)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import LayerGraph, LayerNode, fuse_blocks, linear_graph


def _spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _relu_node(name="relu"):
    return LayerNode(name=name, kind="act", apply=jax.nn.relu)


def _dense_node(d_in, d_out, name="dense", key=0):
    w = jax.random.normal(jax.random.PRNGKey(key), (d_in, d_out)) * 0.02
    return LayerNode(name=name, kind="dense", apply=lambda x: x @ w,
                     flops=2.0 * d_in * d_out, param_bytes=4 * d_in * d_out)


def make_linear(n_layers=5, d=8):
    layers = [_dense_node(d, d, name=f"fc{i}", key=i) for i in range(n_layers)]
    return linear_graph("lin", _spec(1, d), layers)


def make_branching(d=8):
    """input -> a -> (b1 | b2) -> add -> c : only cuts after a, after add."""
    g = LayerGraph("branch")
    i = g.input(_spec(1, d))
    a = g.add(_dense_node(d, d, "a", 1), [i])
    b1 = g.add(_dense_node(d, d, "b1", 2), [a])
    b2 = g.add(_dense_node(d, d, "b2", 3), [a])
    add = g.add(LayerNode("add", "merge", apply=lambda x, y: x + y), [b1, b2])
    c = g.add(_dense_node(d, d, "c", 4), [add])
    g.trace()
    return g


class TestLinear:
    def test_partition_points_n_minus_2(self):
        # N layers + input node; paper: N-2 points for an N-layer linear DNN
        # (our node count includes the input => points = n_nodes - 2).
        g = make_linear(5)
        assert len(g.partition_points()) == g.n_layers - 2

    def test_blocks_cover_all_nodes(self):
        g = make_linear(6)
        blocks = fuse_blocks(g)
        ids = [i for b in blocks for i in b.node_ids]
        assert ids == list(range(g.n_layers))

    def test_first_block_absorbs_input(self):
        g = make_linear(4)
        blocks = fuse_blocks(g)
        assert blocks[0].node_ids[:2] == [0, 1]  # input fused with layer 1

    def test_output_bytes(self):
        g = make_linear(3, d=8)
        blocks = fuse_blocks(g)
        for b in blocks:
            assert b.output_bytes == 8 * 4  # (1, 8) float32


class TestBranching:
    def test_branch_fused_into_block(self):
        g = make_branching()
        points = g.partition_points()
        # valid cuts: after 'a' (idx 1) and after 'add' (idx 4) only
        assert points == [1, 4]
        blocks = fuse_blocks(g)
        assert len(blocks) == 3
        assert blocks[1].kinds == ["dense", "dense", "merge"]

    def test_block_callable_matches_full_graph(self):
        g = make_branching()
        blocks = fuse_blocks(g)
        x = jax.random.normal(jax.random.PRNGKey(7), (1, 8))
        # full graph
        vals = [x]
        for i in range(1, g.n_layers):
            ins = [vals[p] for p in g.preds[i]]
            vals.append(g.nodes[i].apply(*ins))
        want = vals[-1]
        # block chain
        y = x
        for b in blocks:
            y = b.make_callable()(y)
        assert jnp.allclose(y, want, atol=1e-6)

    def test_invalid_graph_rejected(self):
        g = LayerGraph("bad")
        g.input(_spec(1, 4))
        g.add(_dense_node(4, 4, "x", 0), [0])
        g.add(_dense_node(4, 4, "dangling", 1), [0])  # second sink
        with pytest.raises(ValueError):
            g.validate()


def test_crossing_counts_monotone_bounds():
    g = make_branching()
    counts = g.crossing_counts()
    assert counts[-1] == 0            # nothing crosses after the sink
    assert all(c >= 1 for c in counts[:-1])
