"""Shared test configuration.

Provides a fallback implementation of the ``flaky(reruns=N)`` mark for
environments where ``pytest-rerunfailures`` is not installed (the container
running tier-1 has no network access): marked tests are re-run up to N times
and only the final attempt is reported.  When the real plugin is present it
takes over and this hook stands down.
"""

from _pytest.runner import runtestprotocol


def _has_rerun_plugin(config) -> bool:
    return config.pluginmanager.hasplugin("rerunfailures")


def pytest_runtest_protocol(item, nextitem):
    marker = item.get_closest_marker("flaky")
    if marker is None or _has_rerun_plugin(item.config):
        return None
    reruns = int(marker.kwargs.get("reruns",
                                   marker.args[0] if marker.args else 1))
    item.ihook.pytest_runtest_logstart(nodeid=item.nodeid,
                                       location=item.location)
    for attempt in range(reruns + 1):
        reports = runtestprotocol(item, nextitem=nextitem, log=False)
        if not any(r.failed for r in reports) or attempt == reruns:
            for r in reports:
                item.ihook.pytest_runtest_logreport(report=r)
            break
    item.ihook.pytest_runtest_logfinish(nodeid=item.nodeid,
                                        location=item.location)
    return True
