"""Constraint-exact lattices: the path-dependent constraints
(``max_resource_time`` / ``min_blocks_on``) are folded into the DP state
of all three lattices, so every ``solve()``/frontier equals the exhaustive
oracle even when a constraint binds hard enough that the old post-filtered
k-best pools returned fewer — or zero — results.

Costs are dyadic (times a/2^10, power-of-two bandwidths), so every
cost-model sum/max/division is exact in float64 and strategies can be
compared with exact equality.  Also covers the satellite regressions that
shipped with the tentpole: the BottleneckLattice wide-tie Pareto dispatch,
the elastic controller's single-solve frontier re-plan + warm start, and
the pipeline simulator's steady-state window / replica validation.
"""

import itertools

import numpy as np
import pytest

from repro.core import (Constraints, CostModel, LATENCY, Link, NetworkModel,
                        ParetoLattice, Query, QueryEngine, Resource,
                        enumerate_partitions, objective_vector,
                        pareto_frontier, rank)
from repro.core.partition import BottleneckLattice, PartitionLattice, Segment
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
import repro.core.query as query_mod

from test_frontier_exact import _grid_space, _make_db

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # degrade to the deterministic tests only
    HAVE_HYPOTHESIS = False

_vec = objective_vector


def _oracle(eng, cons, cost):
    """Exhaustively enumerated feasible set (the validation oracle)."""
    return [c for c in enumerate_partitions(cost)
            if eng._config_satisfies(c, cons, cost)]


def _random_engine_and_query(seed):
    """A random small space with dyadic costs plus a *path-dependent*
    constraint draw: a compute-time cap at a fraction of a resource's total
    time (often binding, sometimes unsatisfiable) and/or a min-block floor
    in 1..n_blocks+1 (n_blocks+1 == infeasible on purpose)."""
    rng = np.random.default_rng(seed)
    n_blocks = int(rng.integers(3, 7))
    batches = (1,) if rng.integers(2) else (1, 2)
    res = [Resource("device0", "device", RPI4)]
    res += [Resource(f"edge{i}", "edge", EDGE_BOX_1)
            for i in range(int(rng.integers(0, 3)))]
    res += [Resource(f"cloud{i}", "cloud", CLOUD_VM)
            for i in range(int(rng.integers(1, 3)))]
    names = [r.name for r in res]
    times = {}
    for r in names:
        for b in range(n_blocks):
            t1 = int(rng.integers(1, 1 << 10)) / (1 << 10)
            times[(r, b, 1)] = t1
            if 2 in batches:
                times[(r, b, 2)] = t1 + int(rng.integers(0, 1 << 10)) / (1 << 10)
    out_bytes = [int(rng.integers(1, 1 << 14)) for _ in range(n_blocks)]
    db = _make_db("rand", n_blocks, res, times, out_bytes, batches)

    def link(tag):
        return Link(tag, int(rng.integers(0, 1 << 6)) / (1 << 10),
                    float(1 << int(rng.integers(14, 23))))

    net = NetworkModel(default=link("d"))
    for a, b in itertools.permutations(names, 2):
        if rng.random() < 0.4:
            net.connect(a, b, link(f"{a}-{b}"), symmetric=False)
    eng = QueryEngine(db, res, net, source="device0",
                      input_bytes=float(rng.integers(1, 1 << 16)))
    kw = {}
    kind = int(rng.integers(3))          # 0: tmax, 1: nmin, 2: both
    if kind in (0, 2):
        r = str(rng.choice(names))
        total = sum(times[(r, b, 1)] for b in range(n_blocks))
        frac = [0.25, 0.5, 0.75][int(rng.integers(3))]   # dyadic
        kw["max_resource_time"] = {r: total * frac}
    if kind in (1, 2):
        r = str(rng.choice(names))
        kw["min_blocks_on"] = {r: int(rng.integers(1, n_blocks + 2))}
    if rng.integers(2):
        kw["must_use"] = (str(rng.choice(names)),)
    if rng.integers(2):
        kw["replicas"] = {str(rng.choice(names)): 2}
    return eng, Query(batch_sizes=batches, **kw)


def _assert_all_lattices_match_oracle(seed):
    """Acceptance property: with binding path-dependent constraints, each
    lattice's solve()/frontier equals the exhaustive oracle — including
    the under-fill cases (oracle non-empty, old lattices returned fewer or
    zero results) and the genuinely infeasible ones (both empty)."""
    eng, query = _random_engine_and_query(seed)
    cons = query.constraints()
    cost = eng._cost_for(query)
    feas = _oracle(eng, cons, cost)
    # k-best additive DP: exact score sequence, all results feasible
    for top_n in (1, 5):
        got = PartitionLattice(cost, cons).solve(top_n=top_n)
        want = rank(feas, LATENCY, top_n)
        assert [c.latency_s for c in got] == [c.latency_s for c in want]
        for c in got:
            assert eng._config_satisfies(c, cons, cost)
    # minimax DP: exact constrained optimum with exact latency tie-break
    got_b = BottleneckLattice(cost, cons).solve(top_n=1)
    if feas:
        wb = min(c.bottleneck_s for c in feas)
        wl = min(c.latency_s for c in feas if c.bottleneck_s == wb)
        assert got_b, "feasible space must not yield an empty result"
        assert got_b[0].bottleneck_s == wb
        assert got_b[0].latency_s == wl
    else:
        assert got_b == []
    # label-correcting DP: exact constrained frontier
    got_f = {_vec(c) for c in ParetoLattice(cost, cons).solve()}
    assert got_f == {_vec(c) for c in pareto_frontier(feas)}
    # engine strategies agree across the swept operating points
    exh = eng.frontier(query, strategy="exhaustive")
    lat = eng.frontier(query, strategy="lattice")
    assert {_vec(c) for c in lat.configs} == {_vec(c) for c in exh.configs}


@pytest.mark.parametrize("seed", range(25))
def test_constrained_lattices_equal_oracle(seed):
    _assert_all_lattices_match_oracle(seed)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10 ** 9))
    @settings(max_examples=30, deadline=None)
    def test_constrained_lattices_property(seed):
        _assert_all_lattices_match_oracle(seed)


class TestBindingConstraintsDeterministic:
    """The under-fill regression and compound-constraint cases on the
    deterministic grid space."""

    @pytest.mark.parametrize("cons", [
        Constraints(max_resource_time={"device0": 0.05}),
        Constraints(min_blocks_on={"device0": 3}),
        Constraints(min_blocks_on={"edge0": 2},
                    max_resource_time={"edge0": 0.25}),
        Constraints(min_blocks_on={"device0": 2, "cloud0": 2}),
        Constraints(must_use=("edge1",), max_resource_time={"edge1": 0.05}),
    ])
    def test_lattices_match_oracle(self, cons):
        eng = _grid_space()
        cost = eng.cost
        feas = _oracle(eng, cons, cost)
        assert feas, "scenario must be feasible for the under-fill check"
        got = PartitionLattice(cost, cons).solve(top_n=4)
        want = rank(feas, LATENCY, 4)
        assert [c.latency_s for c in got] == [c.latency_s for c in want]
        got_f = {_vec(c) for c in ParetoLattice(cost, cons).solve()}
        assert got_f == {_vec(c) for c in pareto_frontier(feas)}

    def test_binding_constraint_cannot_underfill(self):
        """Regression: a floor demanding nearly every block on the slow
        device rejects every unconstrained pool winner; the old
        post-filter returned fewer (often zero) results even though
        feasible configs exist."""
        eng = _grid_space()
        cost = eng.cost
        cons = Constraints(min_blocks_on={"device0": cost.n_blocks - 1})
        feas = _oracle(eng, cons, cost)
        assert feas
        got = PartitionLattice(cost, cons).solve(top_n=3)
        assert len(got) == min(3, len(feas))
        assert got[0].latency_s == min(c.latency_s for c in feas)
        got_b = BottleneckLattice(cost, cons).solve(top_n=1)
        assert got_b and got_b[0].bottleneck_s == \
            min(c.bottleneck_s for c in feas)

    def test_unsatisfiable_floor_matches_oracle_empty(self):
        eng = _grid_space()
        cost = eng.cost
        for cons in (Constraints(min_blocks_on={"device0": 99}),
                     Constraints(min_blocks_on={"nosuch": 1}),
                     Constraints(exclude=("edge0",),
                                 min_blocks_on={"edge0": 1}),
                     Constraints(max_resource_time={"cloud0": 0.0},
                                 must_use=("cloud0",))):
            assert _oracle(eng, cons, cost) == []
            assert PartitionLattice(cost, cons).solve(top_n=3) == []
            assert BottleneckLattice(cost, cons).solve(top_n=3) == []
            assert ParetoLattice(cost, cons).solve() == []

    def test_zero_floor_is_trivially_satisfied(self):
        """path_feasible accepts an absent resource at floor 0, so the
        lattice must not fold a zero floor into the must-use mask."""
        eng = _grid_space()
        cost = eng.cost
        cons = Constraints(min_blocks_on={"cloud0": 0})
        free = PartitionLattice(cost).solve(top_n=3)
        got = PartitionLattice(cost, cons).solve(top_n=3)
        assert [c.latency_s for c in got] == [c.latency_s for c in free]

    def test_run_strategies_agree_on_constrained_query(self, monkeypatch):
        q = Query(top_n=3, max_resource_time={"device0": 0.05},
                  min_blocks_on={"edge0": 2})
        want = _grid_space().run(q)
        assert want.strategy == "exhaustive" and want.configs
        monkeypatch.setattr(query_mod, "EXHAUSTIVE_LIMIT", -1)
        got = _grid_space().run(q)
        assert got.strategy == "lattice"
        assert [c.latency_s for c in got.configs] == \
            [c.latency_s for c in want.configs]

    def test_restricted_pipelines_with_floor(self):
        """Per-pipe lattice solves skip pipes that cannot host a demanded
        floor and stay oracle-exact on the rest."""
        eng = _grid_space()
        q = Query(min_blocks_on={"cloud0": 2},
                  pipelines=(("device0", "edge0"),       # no cloud0 -> dead
                             ("device0", "cloud0"),
                             ("device0", "edge0", "cloud0")))
        exh = eng.frontier(q, strategy="exhaustive")
        lat = eng.frontier(q, strategy="lattice")
        assert exh.configs
        assert {_vec(c) for c in lat.configs} == \
            {_vec(c) for c in exh.configs}
        for c in lat.configs:
            assert sum(s.end - s.start + 1 for s in c.segments
                       if s.resource == "cloud0") >= 2


class TestBottleneckWideTies:
    def test_tie_wider_than_pool_dispatches_to_pareto(self):
        """Regression (ROADMAP follow-up): a bottleneck tie wider than a
        state's k-best pool used to cut the lowest-latency tied config
        inside the DP; the solver must detect the cut and reconstruct the
        tied surface via ParetoLattice dispatch."""
        res = [Resource("device0", "device", RPI4)]
        res += [Resource(f"edge{i}", "edge", EDGE_BOX_1) for i in range(6)]
        res += [Resource("cloud0", "cloud", CLOUD_VM)]
        n_blocks = 2
        times = {}
        for r in res:
            # device: cheap first block, prohibitive second (native device
            # never ties); edges equal; cloud strictly fastest and LAST in
            # insertion order, so the tied pool drops it first
            t = {"device": [1 / 64, 4.0], "edge": [1 / 8, 1 / 8],
                 "cloud": [1 / 32, 1 / 32]}[r.tier]
            for b in range(n_blocks):
                times[(r.name, b, 1)] = t[b]
        out_bytes = [1 << 20] * n_blocks
        db = _make_db("ties", n_blocks, res, times, out_bytes)
        # shared hop time 1.0 dominates every stage -> every device->X
        # config ties at bottleneck 1.0; a huge input keeps off-device
        # starts above the tie
        net = NetworkModel(default=Link("slow", 0.0, float(1 << 20)))
        cost = CostModel(db=db, resources=res, network=net, source="device0",
                         input_bytes=float(1 << 22))
        configs = enumerate_partitions(cost)
        best_b = min(c.bottleneck_s for c in configs)
        tied = [c for c in configs if c.bottleneck_s == best_b]
        lattice = BottleneckLattice(cost)
        K = max(1 * 2, 1 + 2)
        assert len(tied) > K, "scenario must out-tie the k-best pool"
        oracle = min(tied, key=lambda c: c.latency_s)
        got = lattice.solve(top_n=1)[0]
        assert lattice._dispatched       # the cut tie was detected
        assert got.bottleneck_s == pytest.approx(best_b)
        assert got.resources == ("device0", "cloud0")
        assert got.latency_s == pytest.approx(oracle.latency_s)

    def test_unique_winner_skips_pareto_dispatch(self):
        """Regression: the dispatch trigger compared a dropped *suffix*
        value (which excludes the input hop / prefix maximum) against the
        full-path winner, so it fired on essentially every solve and paid
        a full ParetoLattice extraction; a unique winner proves no tie was
        cut, so the dispatch must stay off."""
        eng = _grid_space()
        lattice = BottleneckLattice(eng.cost)
        got = lattice.solve(top_n=1)
        assert got
        assert not lattice._dispatched

    @pytest.mark.parametrize("seed", range(6))
    def test_rank0_latency_tie_break_exact_on_random_spaces(self, seed):
        eng, query = _random_engine_and_query(seed)
        cost = eng._cost_for(query)
        cons = query.constraints()
        feas = _oracle(eng, cons, cost)
        got = BottleneckLattice(cost, cons).solve(top_n=1)
        if not feas:
            assert got == []
            return
        wb = min(c.bottleneck_s for c in feas)
        assert got[0].bottleneck_s == wb
        assert got[0].latency_s == min(c.latency_s for c in feas
                                       if c.bottleneck_s == wb)


class TestElasticSingleSolve:
    def _scission(self, link=None, batches=(1,)):
        from repro.core import Scission, AnalyticProvider, linear_graph
        from repro.core.graph import LayerNode
        import jax, jax.numpy as jnp
        layers = [LayerNode(f"l{i}", "dense",
                            apply=lambda x: x * 1.0,
                            flops=float((i + 1) * 5e7)) for i in range(5)]
        g = linear_graph("toy-ce", jax.ShapeDtypeStruct((1, 8), jnp.float32),
                         layers)
        res = [Resource("device", "device", RPI4, speed_factor=30.0),
               Resource("edge1", "edge", EDGE_BOX_1, speed_factor=3.0),
               Resource("cloud", "cloud", CLOUD_VM, speed_factor=1.0)]
        net = NetworkModel(default=link or Link("l", 0.01, 1e6))
        s = Scission(resources=res, network=net, source="device",
                     provider=AnalyticProvider(), runs=1)
        s.benchmark(g, batch_sizes=batches)
        return s

    def test_frontier_mode_replans_with_one_solve(self, monkeypatch):
        """Satellite: frontier-mode re-plans used to run scission.query()
        AND scission.frontier() — two full solves; the config now derives
        from the extracted frontier, so query() is never called."""
        from repro.core import Scission
        from repro.runtime.elastic import ElasticController
        calls = {"query": 0}
        orig = Scission.query

        def spy(self, *a, **kw):
            calls["query"] += 1
            return orig(self, *a, **kw)

        monkeypatch.setattr(Scission, "query", spy)
        s = self._scission()
        ctl = ElasticController(s, "toy-ce", track_frontier=True)
        ctl.on_network_change(NetworkModel(default=Link("f", 0.0, 1e12)))
        assert calls["query"] == 0
        # non-frontier mode still goes through query()
        ctl2 = ElasticController(self._scission(), "toy-ce")
        assert ctl2.current is not None
        assert calls["query"] == 1

    def test_config_is_objective_best_frontier_point(self):
        from repro.runtime.elastic import ElasticController
        s = self._scission()
        want = s.frontier("toy-ce", Query(top_n=1)).configs
        ctl = ElasticController(s, "toy-ce", track_frontier=True)
        ev = ctl.history[0]
        assert ev.frontier is not None
        assert _vec(ev.config) in {_vec(c) for c in ev.frontier}
        assert ev.config.latency_s == min(c.latency_s for c in want)

    def test_warm_start_revalidates_previous_surface(self):
        from repro.runtime.elastic import ElasticController
        s = self._scission()
        ctl = ElasticController(s, "toy-ce", track_frontier=True)
        prev = ctl.history[0].frontier
        assert prev
        ev = ctl.on_resource_lost("edge1")
        # warm-start candidates never resurrect the lost resource and are
        # re-priced/feasible under the new membership
        cands = ctl._warm_start_candidates(prev)
        assert all("edge1" not in c.resources for c in cands)
        assert all("edge1" not in c.resources for c in ev.frontier)
        # the merged surface is still the exact frontier at the new state
        fresh = ctl.scission.frontier("toy-ce", ctl.query).configs
        assert {_vec(c) for c in ev.frontier} == {_vec(c) for c in fresh}
        assert ctl.last_frontier_shift() is not None

    def test_frontier_mode_preserves_operating_point(self):
        """Regression: deriving the config from a frontier swept over
        every measured batch could silently move the plan (and with it
        the serving admission width) to a different batch size; the
        re-plan sweep is pinned to Query.batch_size unless the caller
        explicitly asks for a wider surface."""
        from repro.runtime.elastic import ElasticController
        s = self._scission(batches=(1, 4))
        ctl = ElasticController(s, "toy-ce",
                                query=Query(top_n=1, batch_size=4),
                                track_frontier=True)
        assert ctl.current.batch_size == 4
        ev = ctl.on_resource_lost("edge1")
        assert ev.config.batch_size == 4
        assert all(c.batch_size == 4 for c in ev.frontier)
        # an explicit batch_sizes sweep opts into the wider surface
        ctl2 = ElasticController(
            self._scission(batches=(1, 4)), "toy-ce",
            query=Query(top_n=1, batch_size=4, batch_sizes=(1, 4)),
            track_frontier=True)
        assert ctl2.history[0].frontier

    def test_warm_start_off_still_exact(self):
        from repro.runtime.elastic import ElasticController
        s = self._scission()
        ctl = ElasticController(s, "toy-ce", track_frontier=True,
                                warm_start=False)
        ev = ctl.on_resource_lost("edge1")
        fresh = ctl.scission.frontier("toy-ce", ctl.query).configs
        assert {_vec(c) for c in ev.frontier} == {_vec(c) for c in fresh}


class TestSimulatorWindow:
    def _cfg(self, stage_compute, stage_comm, replicas):
        from repro.core.partition import PartitionConfig
        names = "abcdefgh"
        segs = tuple(Segment(names[i], i, i)
                     for i in range(len(stage_compute)))
        return PartitionConfig(
            model="sim", segments=segs, latency_s=sum(stage_compute),
            compute_s={}, comm_s=sum(stage_comm),
            transfer_bytes=0.0, stage_compute_s=tuple(stage_compute),
            stage_comm_s=tuple(stage_comm), replicas=tuple(replicas))

    def test_rejects_replicas_below_one(self):
        from repro.serving.engine import simulate_pipeline_throughput
        for bad in ((0,), (2, 0), (-1, 1)):
            cfg = self._cfg([1.0] * len(bad), [0.0] * (len(bad) - 1), bad)
            with pytest.raises(ValueError, match="replicas"):
                simulate_pipeline_throughput(cfg)

    def test_window_aligns_to_joint_period(self):
        """Regression: a replicated stage drains in bursts (8 finishes per
        wrap), so a measurement window cutting the joint period mid-wrap
        biased the rate by ~3% at n_requests=34; the window must start
        after every replica set wrapped twice and cover whole periods."""
        from repro.serving.engine import simulate_pipeline_throughput
        cfg = self._cfg([8.0, 0.5], [0.5], [8, 1])
        pred = cfg.throughput_rps
        assert pred == pytest.approx(1.0)
        for n in (2, 34, 256):
            sim = simulate_pipeline_throughput(cfg, n_requests=n)
            assert sim == pytest.approx(pred, rel=1e-9), n

    def test_mixed_replica_counts_measure_exact_rate(self):
        from repro.serving.engine import simulate_pipeline_throughput
        cfg = self._cfg([3.0, 8.0, 0.25], [0.125, 0.125], [3, 8, 1])
        # bottleneck = max(3/3, 8/8, hops, 0.25) = 1.0
        sim = simulate_pipeline_throughput(cfg, n_requests=50)
        assert sim == pytest.approx(cfg.throughput_rps, rel=1e-9)
