"""Serving plane: arrival traces, router admission/shedding, metrics,
bucketed prefill, warmup, queue-wait stats, and live re-plan swaps."""

import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AnalyticProvider, Query, Resource, Scission,
                        THROUGHPUT, paper_network, FOUR_G)
from repro.core.partition import PartitionConfig, Segment
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
from repro.models import build_model, cnn_zoo, get_config
from repro.runtime.elastic import ElasticController
from repro.serving import (ExecutorBackend, PROMPT_BUCKETS, Request, Router,
                           ServingEngine, StageQueue, VirtualBackend,
                           bucket_for, bursty_diurnal_trace, empirical_rate,
                           mean, percentile, poisson_trace)
from repro.serving.router import stage_layout


def _point(batch=2, replicas=(1, 1)):
    return PartitionConfig(
        model="m", segments=(Segment("edge1", 0, 3), Segment("cloud", 3, 8)),
        latency_s=0.12, compute_s={"edge1": 0.04, "cloud": 0.05},
        comm_s=0.02, transfer_bytes=1e5, input_comm_s=0.01,
        stage_compute_s=(0.04, 0.05), stage_comm_s=(0.02,),
        batch_size=batch, replicas=replicas)


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------

class TestTraces:
    def test_poisson_deterministic(self):
        a = poisson_trace(rate_rps=10, horizon_s=20, seed=7)
        b = poisson_trace(rate_rps=10, horizon_s=20, seed=7)
        assert a == b
        c = poisson_trace(rate_rps=10, horizon_s=20, seed=8)
        assert a != c

    def test_poisson_empirical_rate(self):
        tr = poisson_trace(rate_rps=50, horizon_s=60, seed=0)
        # ~3000 arrivals: the empirical rate concentrates near nominal
        assert empirical_rate(tr) == pytest.approx(50, rel=0.10)
        assert all(0 <= a.t < 60 for a in tr)
        assert [a.t for a in tr] == sorted(a.t for a in tr)
        assert [a.rid for a in tr] == list(range(len(tr)))

    def test_poisson_prompt_len_range(self):
        tr = poisson_trace(rate_rps=20, horizon_s=20, seed=1,
                           prompt_len=(4, 9), max_new_tokens=3)
        assert all(4 <= a.prompt_len <= 9 for a in tr)
        assert all(a.max_new_tokens == 3 for a in tr)
        assert len({a.prompt_len for a in tr}) > 1

    def test_poisson_validation(self):
        with pytest.raises(ValueError, match="rate"):
            poisson_trace(rate_rps=0, horizon_s=10)
        with pytest.raises(ValueError, match="horizon"):
            poisson_trace(rate_rps=1, horizon_s=0)

    def test_bursty_deterministic_and_bounded(self):
        kw = dict(base_rps=5, peak_rps=40, horizon_s=40, period_s=20,
                  seed=3, burst_factor=2.0, burst_every_s=10, burst_len_s=1)
        a = bursty_diurnal_trace(**kw)
        assert a == bursty_diurnal_trace(**kw)
        r = empirical_rate(a)
        # diurnal mean is (base+peak)/2; bursts only add — stay in band
        assert 5 < r < 80

    def test_bursty_peak_exceeds_base_rate(self):
        """The diurnal envelope is visible: mid-period windows (sin^2 near
        1) are denser than start-of-period windows (sin^2 near 0)."""
        tr = bursty_diurnal_trace(base_rps=2, peak_rps=50, horizon_s=40,
                                  period_s=40, seed=0)
        early = sum(a.t < 8 for a in tr)           # sin^2 < 0.35
        mid = sum(16 <= a.t < 24 for a in tr)      # sin^2 > 0.9
        assert mid > 3 * early

    def test_bursty_validation(self):
        with pytest.raises(ValueError, match="base_rps"):
            bursty_diurnal_trace(base_rps=5, peak_rps=2, horizon_s=10,
                                 period_s=5)
        with pytest.raises(ValueError, match="burst_factor"):
            bursty_diurnal_trace(base_rps=1, peak_rps=2, horizon_s=10,
                                 period_s=5, burst_factor=0.5)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

class TestPercentile:
    def test_nearest_rank_is_a_sample(self):
        xs = [5.0, 1.0, 9.0, 3.0, 7.0]
        assert percentile(xs, 50) == 5.0           # median of odd length
        assert percentile(xs, 100) == 9.0
        assert percentile(xs, 1) == 1.0
        for p in (10, 25, 50, 75, 90, 99):
            assert percentile(xs, p) in xs

    def test_exact_rank_boundaries(self):
        assert percentile([1, 2, 3, 4], 50) == 2   # rank ceil(2.0) = 2
        assert percentile([1, 2, 3, 4], 75) == 3
        assert percentile([1, 2, 3, 4], 76) == 4
        # p99 of 10 samples is the max (rank ceil(9.9) = 10)
        assert percentile(list(range(10)), 99) == 9

    def test_empty_and_validation(self):
        assert percentile([], 50) == 0.0
        assert mean([]) == 0.0
        assert mean([1.0, 3.0]) == 2.0
        with pytest.raises(ValueError, match="percentile"):
            percentile([1], 0)
        with pytest.raises(ValueError, match="percentile"):
            percentile([1], 101)


class TestStageQueue:
    def test_bounded_push(self):
        q = StageQueue(limit=2)
        assert q.push("a") and q.push("b")
        assert not q.push("c")
        assert q.offered == 3 and q.rejected == 1
        assert q.pop() == "a" and len(q) == 1
        assert q.peak_depth == 2
        assert q.depth_histogram == {0: 1, 1: 1, 2: 1}

    def test_bucket_for(self):
        assert bucket_for(1, PROMPT_BUCKETS) == 16
        assert bucket_for(16, PROMPT_BUCKETS) == 16
        assert bucket_for(17, PROMPT_BUCKETS) == 32
        assert bucket_for(5000, PROMPT_BUCKETS) == 5000   # escape hatch
        with pytest.raises(ValueError):
            bucket_for(0, PROMPT_BUCKETS)


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------

class TestRouter:
    def test_under_capacity_completes_everything(self):
        point = _point()
        tr = poisson_trace(rate_rps=0.4 * point.throughput_rps,
                           horizon_s=60, seed=0)
        rep = Router(point, slo_s=2.0).serve(tr)
        assert rep.arrivals == len(tr)
        assert rep.shed == 0 and rep.completed == rep.arrivals
        assert rep.goodput_rps == pytest.approx(rep.offered_rps, rel=0.15)
        assert rep.latency_p50_s <= rep.latency_p99_s
        assert rep.slo_violations == 0

    def test_saturated_goodput_tracks_prediction(self):
        point = _point()
        pred = point.throughput_rps
        tr = poisson_trace(rate_rps=1.3 * pred, horizon_s=120, seed=1)
        rep = Router(point, slo_s=None).serve(tr)
        assert rep.goodput_rps == pytest.approx(pred, rel=0.10)
        assert rep.arrivals == rep.completed + rep.shed

    def test_replicas_scale_capacity(self):
        """Doubling the bottleneck stage's replicas roughly doubles the
        sustained rate (comm hops become the new bottleneck)."""
        lo = Router(_point()).serve(
            poisson_trace(rate_rps=120, horizon_s=60, seed=2))
        hi = Router(_point(replicas=(2, 2))).serve(
            poisson_trace(rate_rps=120, horizon_s=60, seed=2))
        assert hi.goodput_rps > 1.5 * lo.goodput_rps

    def test_queue_full_sheds(self):
        point = _point()
        tr = poisson_trace(rate_rps=5 * point.throughput_rps,
                           horizon_s=60, seed=3)
        rep = Router(point, queue_limit=4).serve(tr)
        assert rep.shed > 0
        assert rep.shed_reasons.get("queue-full", 0) > 0
        assert rep.arrivals == rep.completed + rep.shed

    def test_slo_sheds_at_front_door(self):
        point = _point()
        tr = poisson_trace(rate_rps=3 * point.throughput_rps,
                           horizon_s=60, seed=4)
        slo = 4 * point.latency_s
        rep = Router(point, slo_s=slo, queue_limit=None).serve(tr)
        assert rep.shed_reasons.get("slo", 0) > 0
        assert rep.arrivals == rep.completed + rep.shed
        # admission control did its job: completions honor the SLO (the
        # shadow estimate is exact for full batches; partial-batch age-out
        # may add bounded extra wait)
        assert rep.slo_violations <= 0.1 * rep.completed

    def test_arrivals_must_be_ordered(self):
        r = Router(_point())
        from repro.serving import Arrival
        r.offer(Arrival(t=1.0, rid=0))
        with pytest.raises(ValueError, match="time order"):
            r.offer(Arrival(t=0.5, rid=1))

    def test_queue_depth_histogram_sampled(self):
        point = _point()
        tr = poisson_trace(rate_rps=2 * point.throughput_rps,
                           horizon_s=30, seed=5)
        rep = Router(point).serve(tr)
        assert sum(rep.queue_depth_hist.values()) == rep.arrivals
        assert rep.queue_wait_p99_s >= rep.queue_wait_mean_s >= 0

    def test_live_swap_drops_nothing(self):
        point = _point()
        tr = poisson_trace(rate_rps=1.5 * point.throughput_rps,
                           horizon_s=60, seed=6)
        r = Router(point)
        for a in tr:
            if a.t >= 30 and not r.swaps:
                drained = r.set_operating_point(
                    dataclasses.replace(point, replicas=(2, 2)))
                assert drained >= 30
            r.offer(a)
        r.flush()
        rep = r.report()
        assert rep.swaps == 1
        assert rep.arrivals == rep.completed + rep.shed
        assert rep.completed > 0

    def test_on_plan_adapter(self):
        r = Router(_point(batch=2))
        new = _point(batch=4)
        r.on_plan(SimpleNamespace(config=new))
        assert r.point is new and r.width == 4
        assert len(r.swaps) == 1

    def test_whole_model_point_single_stage(self):
        """A point evaluated without per-stage times serves as one stage
        at its end-to-end latency."""
        point = PartitionConfig(
            model="m", segments=(Segment("cloud", 0, 8),), latency_s=0.2,
            compute_s={"cloud": 0.2}, comm_s=0.0, transfer_bytes=0.0)
        assert stage_layout(point) == [("compute", 0.2, 1)]
        rep = Router(point).serve(poisson_trace(2, 20, seed=0))
        assert rep.completed == rep.arrivals


# ---------------------------------------------------------------------------
# elastic controller -> router wiring
# ---------------------------------------------------------------------------

class TestElasticWiring:
    def _scission(self):
        res = [Resource("device", "device", RPI4),
               Resource("edge1", "edge", EDGE_BOX_1),
               Resource("cloud", "cloud", CLOUD_VM)]
        net = paper_network(FOUR_G, edges=("edge1",), clouds=("cloud",))
        return Scission(resources=res, network=net, source="device",
                        provider=AnalyticProvider(), runs=1)

    def test_replan_swaps_router_live(self):
        s = self._scission()
        s.benchmark(cnn_zoo.build("MobileNet"))
        ctl = ElasticController(s, "MobileNet",
                                query=Query(objective=THROUGHPUT))
        router = Router(ctl.current)
        ctl.add_listener(router.on_plan)
        tr = poisson_trace(rate_rps=1.2 * ctl.current.throughput_rps,
                           horizon_s=20, seed=0)
        half = len(tr) // 2
        for a in tr[:half]:
            router.offer(a)
        lost = next(r for r in ctl.current.resources if r != "device")
        ctl.on_resource_lost(lost)
        assert len(router.swaps) == 1          # listener fired
        assert router.point is ctl.current
        for a in tr[half:]:
            router.offer(a)
        router.flush()
        rep = router.report()
        assert rep.arrivals == rep.completed + rep.shed
        assert rep.swaps == 1

    def test_listeners_not_called_for_prior_plans(self):
        s = self._scission()
        s.benchmark(cnn_zoo.build("MobileNet"))
        ctl = ElasticController(s, "MobileNet")
        seen = []
        ctl.add_listener(seen.append)
        assert seen == []                      # initial plan predates it
        ev = ctl.on_network_change(paper_network(
            FOUR_G, edges=("edge1",), clouds=("cloud",)))
        assert seen == [ev]


# ---------------------------------------------------------------------------
# executor backend (runtime pipeline as the plane's substrate)
# ---------------------------------------------------------------------------

class TestExecutorBackend:
    def test_measured_stage_times(self):
        g = cnn_zoo.build("MobileNet")
        res = [Resource("device", "device", RPI4),
               Resource("edge1", "edge", EDGE_BOX_1),
               Resource("cloud", "cloud", CLOUD_VM)]
        net = paper_network(FOUR_G, edges=("edge1",), clouds=("cloud",))
        s = Scission(resources=res, network=net, source="device",
                     provider=AnalyticProvider(), runs=1)
        s.benchmark(g)
        best = s.query(g.name, Query(top_n=1, must_use=("device", "edge1")),
                       input_bytes=150e3).best

        def make_input(batch):
            return jnp.zeros(g.input_spec.shape, g.input_spec.dtype)

        backend = ExecutorBackend(g, make_input, network=s.network,
                                  source="device", runs=2)
        router = Router(best, backend=backend)
        times = backend.stage_times()
        assert len(times) == len(stage_layout(best))
        assert all(t >= 0 for t in times)
        # measured compute replaces predicted; hops keep modeled times
        kinds = [k for k, _, _ in stage_layout(best)]
        assert sum(times[i] for i, k in enumerate(kinds)
                   if k == "compute") > 0
        rep = router.serve(poisson_trace(rate_rps=5, horizon_s=5, seed=0))
        assert rep.completed == rep.arrivals


# ---------------------------------------------------------------------------
# serving engine: bucketed prefill, warmup, queue-wait stats
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("granite-8b").replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=128, remat=False, q_chunk=32, loss_seq_chunk=None)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _greedy_reference(model, params, prompt, n_new, max_len=64):
    cache = model.init_cache(batch=1, max_len=max_len)
    logits, cache = jax.jit(model.prefill)(
        params, jnp.asarray(prompt, jnp.int32)[None], cache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    clen = len(prompt)
    step = jax.jit(model.decode_step)
    for _ in range(n_new - 1):
        logits, cache = step(params, jnp.asarray([[toks[-1]]], jnp.int32),
                             cache, jnp.int32(clen))
        toks.append(int(jnp.argmax(logits[0, -1])))
        clen += 1
    return toks


class TestEnginePlane:
    def test_bucketed_prefill_matches_greedy_mixed_lengths(self, small_model):
        """Same-tick admissions across bucket boundaries (lengths 3..21,
        buckets 16/32/64) must decode exactly like per-request greedy."""
        cfg, model, params = small_model
        rng = np.random.default_rng(9)
        prompts = [rng.integers(0, cfg.vocab, n)
                   for n in (3, 7, 16, 17, 21)]
        n_new = 4
        want = [_greedy_reference(model, params, p, n_new) for p in prompts]
        eng = ServingEngine(model, params, width=5, max_len=64)
        assert eng.prompt_buckets is not None      # attn model: auto on
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
        done = sorted(eng.run(), key=lambda r: r.rid)
        for r, w in zip(done, want):
            assert r.tokens == w, (r.rid, r.tokens, w)

    def test_exact_path_still_available(self, small_model):
        cfg, model, params = small_model
        rng = np.random.default_rng(10)
        prompt = rng.integers(0, cfg.vocab, 5)
        want = _greedy_reference(model, params, prompt, 3)
        eng = ServingEngine(model, params, width=2, max_len=64,
                            prompt_buckets=None)
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
        (done,) = eng.run()
        assert done.tokens == want

    def test_single_token_prompt(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, width=1, max_len=32)
        eng.submit(Request(rid=0, prompt=np.array([7]), max_new_tokens=2))
        (done,) = eng.run()
        assert len(done.tokens) == 2

    def test_warmup_precompiles(self, small_model):
        cfg, model, params = small_model
        rng = np.random.default_rng(11)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 6),
                        max_new_tokens=3) for i in range(3)]
        eng = ServingEngine(model, params, width=2, max_len=32)
        for r in reqs:
            eng.submit(r)
        assert eng.warmup() is eng                 # chains; idempotent
        eng.warmup()
        done = eng.run()
        assert len(done) == 3
        # warmup left the engine untouched: nothing admitted, pool empty
        eng2 = ServingEngine(model, params, width=2, max_len=32).warmup()
        assert len(eng2.pool.free) == 2 and not eng2.active

    def test_queue_wait_stats(self, small_model):
        cfg, model, params = small_model
        rng = np.random.default_rng(12)
        # width 1 + 4 requests: later requests measurably wait for a slot
        eng = ServingEngine(model, params, width=1, max_len=32)
        for i in range(4):
            eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4),
                               max_new_tokens=3))
        done = eng.run()
        assert all(r.admitted_at is not None for r in done)
        assert all(r.queue_wait_s >= 0 for r in done)
        assert eng.stats.queue_wait_p99_s >= eng.stats.queue_wait_mean_s > 0

    def test_prompt_too_long_rejected(self, small_model):
        cfg, model, params = small_model
        eng = ServingEngine(model, params, width=1, max_len=16)
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(Request(rid=0, prompt=np.zeros(16, np.int32)))


class TestCompatShim:
    def test_old_engine_imports_still_work(self):
        from repro.serving.engine import (KVCachePool, Request,
                                          ServingEngine, ServingStats,
                                          simulate_pipeline_throughput)
        assert callable(simulate_pipeline_throughput)
        assert ServingStats().requests_per_s == 0.0
