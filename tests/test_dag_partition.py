"""DAG-general partitioning: SP decomposition, the SP-tree DP vs the
DAG-aware exhaustive oracle, and the downstream plumbing.

The exactness properties follow the repo's established bar
(test_frontier_exact / test_constraint_exact): fabricate benchmark DBs
with *dyadic* times and power-of-two bandwidths so every cost-model
sum/max/division is exact in float64, then require exact equality between
the SPSolver and the exhaustive enumeration over tier-monotone
assignments — top-1 per objective and the full Pareto frontier, across
operating points and under every constraint kind, on seeded and
hypothesis-randomized series-parallel block structures with branch/merge
nesting depth >= 2.
"""

import numpy as np
import pytest

from repro.core import (BenchmarkDB, Constraints, LATENCY, Link,
                        NetworkModel, Query, QueryEngine, Resource,
                        THROUGHPUT, TRANSFER, objective_vector,
                        pareto_frontier, rank)
from repro.core.bench import AnalyticProvider, BlockBenchmark
from repro.core.graph import (BlockDag, LayerGraph, LayerNode, SPNode,
                              fuse_block_dag, fuse_blocks, sp_summary)
from repro.core.network import LOOPBACK
from repro.core.partition import (DagCostModel, SPSolver,
                                  dag_config_satisfies, dag_search_space,
                                  enumerate_dag_partitions)
from repro.core.resources import CLOUD_VM, EDGE_BOX_1, RPI4
import repro.core.query as query_mod

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_vec = objective_vector


# ---------------------------------------------------------------------------
# graph fixtures
# ---------------------------------------------------------------------------

def _spec(*shape):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _node(name, fn, **kw):
    return LayerNode(name=name, kind="dense", apply=fn, **kw)


def _diamond_graph():
    """input -> a -> {b1, b2} -> join -> tail: one 2-branch region."""
    g = LayerGraph("diamond")
    i = g.input(_spec(1, 8))
    a = g.add(_node("a", lambda x: x * 2), [i])
    b1 = g.add(_node("b1", lambda x: x + 1), [a])
    b2 = g.add(_node("b2", lambda x: x * 3), [a])
    j = g.add(_node("join", lambda x, y: x + y), [b1, b2])
    g.add(_node("tail", lambda x: x - 1), [j])
    g.trace()
    return g


def _residual_graph():
    """input -> a -> body -> add(body, a) : single branch + direct edge."""
    g = LayerGraph("residual")
    i = g.input(_spec(1, 8))
    a = g.add(_node("a", lambda x: x * 2), [i])
    b = g.add(_node("body", lambda x: x + 5), [a])
    g.add(_node("add", lambda h, x: x + h), [b, a])
    g.trace()
    return g


def _crossed_graph():
    """a->c and b->d skips cross: NOT series-parallel."""
    g = LayerGraph("crossed")
    i = g.input(_spec(1, 8))
    a = g.add(_node("a", lambda x: x * 2), [i])
    b = g.add(_node("b", lambda x: x + 1), [a])
    c = g.add(_node("c", lambda x, y: x + y), [b, a])
    g.add(_node("d", lambda x, y: x * y), [c, b])
    g.trace()
    return g


def _linear_graph(n=4):
    g = LayerGraph("linear")
    prev = g.input(_spec(1, 8))
    for k in range(n):
        prev = g.add(_node(f"l{k}", lambda x, k=k: x + k), [prev])
    g.trace()
    return g


def _run_graph(g, x):
    import jax.numpy as jnp
    vals = [jnp.asarray(x)]
    for i in range(1, len(g.nodes)):
        vals.append(g.nodes[i].apply(*[vals[p] for p in g.preds[i]]))
    return np.asarray(vals[-1])


class TestSPDecomposition:
    def test_diamond(self):
        dag = _diamond_graph() and fuse_block_dag(_diamond_graph())
        assert [b.node_ids for b in dag] == [[0, 1], [2], [3], [4], [5]]
        assert dag.preds == [[], [0], [0], [1, 2], [3]]
        assert dag.parallel_regions and not dag.collapsed
        assert not dag.is_chain
        kinds = [c.kind for c in dag.tree.children]
        assert "parallel" in kinds

    def test_residual_direct_edge(self):
        dag = fuse_block_dag(_residual_graph())
        assert dag.preds[-1] == [1, 0] or dag.preds[2] == [1, 0]
        par = [c for c in dag.tree.children if c.kind == "parallel"]
        assert par and par[0].direct
        assert not dag.collapsed

    def test_non_sp_collapses_with_diagnosis(self):
        dag = fuse_block_dag(_crossed_graph())
        assert dag.collapsed, "crossed skips must be linearised"
        assert dag.is_chain

    def test_linear_graph_identical_to_chain_fusing(self):
        g = _linear_graph()
        dag = fuse_block_dag(g)
        chain = fuse_blocks(g)
        assert [b.node_ids for b in dag] == [b.node_ids for b in chain]
        assert dag.is_chain and not dag.parallel_regions

    def test_chain_fusing_still_returns_blockdag_in_chain_form(self):
        dag = fuse_blocks(_diamond_graph())
        assert isinstance(dag, BlockDag)
        assert dag.is_chain          # chain fusing never emits branches

    def test_sp_summary_topology_only(self):
        regions, collapsed = sp_summary(_diamond_graph())
        assert regions and not collapsed
        regions, collapsed = sp_summary(_crossed_graph())
        assert collapsed

    def test_multi_entry_block(self):
        dag = fuse_block_dag(_diamond_graph())
        join = dag[3]
        assert join.entry_nodes == [2, 3]
        assert len(join.in_specs) == 2
        with pytest.raises(ValueError, match="entry"):
            join.in_spec
        # numeric equality: DAG block-by-block execution == direct eval
        g = _diamond_graph()
        dag = fuse_block_dag(g)
        x = np.arange(8, dtype=np.float32).reshape(1, 8)
        outs = {}
        owner = {b.node_ids[-1]: b.index for b in dag}
        for b in dag:
            ins = [outs[owner[e]] for e in b.entry_nodes] or [x]
            outs[b.index] = b.make_callable()(*ins)
        assert np.allclose(np.asarray(outs[len(dag) - 1]), _run_graph(g, x))


# ---------------------------------------------------------------------------
# cost-model fixtures (dyadic -> exact float64 arithmetic)
# ---------------------------------------------------------------------------

def _make_db(model, n_blocks, resources, times, out_bytes, batches=(1,)):
    db = BenchmarkDB(model=model, n_blocks=n_blocks)
    for r in resources:
        recs = []
        for b in range(n_blocks):
            profile = {bt: (times[(r.name, b, bt)], out_bytes[b] * bt)
                       for bt in batches}
            recs.append(BlockBenchmark(
                block=b, resource=r.name, mean_time_s=profile[1][0],
                std_time_s=0.0, output_bytes=out_bytes[b], runs=1,
                batch_profile=profile))
        db.records[r.name] = recs
    return db


def _leaf(b):
    return SPNode("leaf", block=b)


def _series(children):
    return SPNode("series", children=list(children))


def _dag_space(seed=0, preds=None, tree=None, batches=(1,)):
    """Diamond (default) or custom SP structure over a 4-resource testbed
    with seeded dyadic costs."""
    rng = np.random.default_rng(seed)
    if preds is None:
        preds = [[], [0], [0], [1, 2], [3]]
        tree = _series([
            _leaf(0),
            SPNode("parallel", children=[_series([_leaf(1)]),
                                         _series([_leaf(2)])]),
            _leaf(3), _leaf(4)])
    B = len(preds)
    res = [Resource("device0", "device", RPI4),
           Resource("edge0", "edge", EDGE_BOX_1),
           Resource("edge1", "edge", EDGE_BOX_1),
           Resource("cloud0", "cloud", CLOUD_VM)]
    times = {}
    for r in res:
        for b in range(B):
            t1 = int(rng.integers(1, 1 << 8)) / (1 << 8)
            for bt in batches:
                times[(r.name, b, bt)] = t1 * bt
    out_bytes = [int(rng.integers(1, 1 << 13)) for _ in range(B)]
    db = _make_db("dag", B, res, times, out_bytes, batches)
    net = NetworkModel(default=Link("d", 1 / (1 << 6), float(1 << 20)))
    net.connect("device0", "edge0", Link("a", 1 / (1 << 8), float(1 << 22)))
    net.connect("edge0", "cloud0", Link("b", 1 / (1 << 7), float(1 << 24)))
    cost = DagCostModel(db=db, resources=res, network=net, source="device0",
                        input_bytes=float(1 << 13), block_preds=preds,
                        tree=tree)
    eng = QueryEngine(db, res, net, source="device0",
                      input_bytes=float(1 << 13),
                      block_preds=preds, sp_tree=tree)
    return cost, eng


_CONSTRAINTS = [
    Constraints(),
    Constraints(must_use=("cloud0",)),
    Constraints(exclude=("edge1",)),
    Constraints(pin={2: "edge0"}),
    Constraints(max_resource_time={"device0": 1 / (1 << 2)}),
    Constraints(min_blocks_on={"edge0": 2}),
    Constraints(must_use=("edge0",), min_blocks_on={"cloud0": 1},
                max_resource_time={"device0": 1 / (1 << 1)}),
]


def _assert_solver_matches_oracle(cost, cons):
    pool = enumerate_dag_partitions(cost)
    ok = [c for c in pool if dag_config_satisfies(cost, c, cons)]
    for obj in (LATENCY, TRANSFER, THROUGHPUT):
        want = rank(ok, obj, 1)
        got = SPSolver(cost, cons).solve(obj, top_n=1)
        assert [obj.score(c) for c in want] == [obj.score(c) for c in got]
        if want:
            # label-for-label: the winning assignment prices identically
            assert _vec(got[0]) in {_vec(c) for c in ok
                                    if obj.score(c) == obj.score(want[0])}
    want_f = {_vec(c) for c in pareto_frontier(ok)}
    got_f = {_vec(c) for c in SPSolver(cost, cons).frontier()}
    assert want_f == got_f


class TestSolverVsOracle:
    @pytest.mark.parametrize("cons", _CONSTRAINTS)
    def test_diamond_matches_oracle(self, cons):
        cost, _ = _dag_space(seed=3)
        _assert_solver_matches_oracle(cost, cons)

    def test_search_space_counts_the_pool(self):
        cost, _ = _dag_space(seed=1)
        assert dag_search_space(cost) == len(enumerate_dag_partitions(cost))

    def test_optimum_splits_a_parallel_region(self):
        """Acceptance: on a space engineered so each branch is fast on a
        different edge box, the solver's best cut set places the two
        branches on distinct resources — and still matches the oracle."""
        cost, _ = _dag_space(seed=0)
        # branch blocks 1 and 2: make edge0 fast for 1, edge1 fast for 2,
        # everything else slow; keep links cheap so the split pays off
        for r in ("device0", "edge0", "edge1", "cloud0"):
            for b in range(5):
                cost.db.records[r][b].batch_profile[1] = (1 / (1 << 1),
                                                          cost.db.records[r][b].batch_profile[1][1])
                cost.db.records[r][b].mean_time_s = 1 / (1 << 1)
        for fast_r, blk in (("edge0", 1), ("edge1", 2)):
            cost.db.records[fast_r][blk].batch_profile[1] = (
                1 / (1 << 10), cost.db.records[fast_r][blk].batch_profile[1][1])
            cost.db.records[fast_r][blk].mean_time_s = 1 / (1 << 10)
        cost.network.default = Link("free", 0.0, float(1 << 40))
        cost2 = DagCostModel(db=cost.db, resources=cost.resources,
                             network=cost.network, source="device0",
                             input_bytes=1.0, block_preds=cost.block_preds,
                             tree=cost.tree)
        best = SPSolver(cost2).solve(LATENCY, top_n=1)[0]
        assert best.assignment[1] != best.assignment[2]
        assert {best.assignment[1], best.assignment[2]} == {"edge0", "edge1"}
        _assert_solver_matches_oracle(cost2, Constraints())

    def test_chain_cost_model_reduces_to_chain_solver(self):
        """On a chain-shaped DagCostModel the SPSolver's optimum equals the
        chain lattice's, objective by objective."""
        from repro.core.partition import (BottleneckLattice,
                                          PartitionLattice)
        preds = [[] if i == 0 else [i - 1] for i in range(5)]
        tree = _series([_leaf(i) for i in range(5)])
        cost, _ = _dag_space(seed=7, preds=preds, tree=tree)
        for obj in (LATENCY, TRANSFER):
            a = SPSolver(cost).solve(obj, top_n=1)
            b = PartitionLattice(cost, objective=obj).solve(top_n=1)
            assert obj.score(a[0]) == obj.score(b[0])
        a = SPSolver(cost).solve(THROUGHPUT, top_n=1)
        b = BottleneckLattice(cost).solve(top_n=1)
        assert THROUGHPUT.score(a[0]) == THROUGHPUT.score(b[0])


# ---------------------------------------------------------------------------
# randomized SP structures (seeded sweep + hypothesis amplifier)
# ---------------------------------------------------------------------------

def _random_sp(rng, depth=2):
    """Random SP block structure with branch nesting up to ``depth``:
    returns (preds, tree).  Guarantees >= one parallel region and branch /
    merge depth >= 2 when depth >= 2 (nested regions inside branches)."""
    preds: list[list[int]] = []
    counter = [0]

    def new_block(ps):
        b = counter[0]
        counter[0] += 1
        preds.append(list(ps))
        return b

    def series(entry, n_units, d, force_par):
        children = []
        tail = entry
        for u in range(n_units):
            make_par = tail is not None and d > 0 and (
                (force_par and u == n_units - 1 and
                 not any(c.kind == "parallel" for c in children))
                or rng.random() < 0.45)
            if make_par:
                k = int(rng.integers(2, 4))
                branches, tails = [], []
                for _ in range(k):
                    bt, btail = series(tail, int(rng.integers(1, 3)),
                                       d - 1, False)
                    branches.append(bt)
                    tails.append(btail)
                direct = bool(rng.integers(2))
                join = new_block(sorted(tails + ([tail] if direct else [])))
                children.append(SPNode("parallel", children=branches,
                                       direct=direct))
                children.append(_leaf(join))
                tail = join
            else:
                b = new_block([] if tail is None else [tail])
                children.append(_leaf(b))
                tail = b
        return _series(children), tail

    tree, _ = series(None, int(rng.integers(3, 5)), depth, True)
    return preds, tree


def _random_dag_case(seed):
    rng = np.random.default_rng(seed)
    preds, tree = _random_sp(rng)
    while len(preds) > 14:      # keep the oracle sweep fast but non-trivial
        preds, tree = _random_sp(rng)
    batches = (1,) if rng.integers(2) else (1, 2)
    cost, eng = _dag_space(seed=seed + 1, preds=preds, tree=tree,
                           batches=batches)
    names = [r.name for r in cost.resources]
    kind = ["none", "must_use", "exclude", "pin", "tmax", "nmin"][
        int(rng.integers(6))]
    kw = {}
    if kind == "must_use":
        kw["must_use"] = (str(rng.choice(names)),)
    elif kind == "exclude":
        kw["exclude"] = (str(rng.choice(names[1:])),)
    elif kind == "pin":
        kw["pin"] = {int(rng.integers(len(preds))): str(rng.choice(names))}
    elif kind == "tmax":
        kw["max_resource_time"] = {
            str(rng.choice(names)): int(rng.integers(1, 1 << 4)) / (1 << 2)}
    elif kind == "nmin":
        kw["min_blocks_on"] = {str(rng.choice(names)): int(rng.integers(1, 3))}
    if rng.integers(2):
        kw["replicas"] = {str(rng.choice(names)): 2}
    return cost, eng, Query(batch_sizes=batches, **kw)


def _assert_dag_case(seed):
    cost, eng, query = _random_dag_case(seed)
    # engine-level: both run() strategies agree score-for-score
    r_ex = eng.run(query)
    assert r_ex.strategy == "exhaustive"
    old = query_mod.EXHAUSTIVE_LIMIT
    try:
        query_mod.EXHAUSTIVE_LIMIT = -1
        r_sp = eng.run(query)
    finally:
        query_mod.EXHAUSTIVE_LIMIT = old
    assert r_sp.strategy == "lattice"
    sc = query.objective.score
    assert [sc(c) for c in r_ex.configs] == [sc(c) for c in r_sp.configs]
    # frontier: exact vector-set equality across the operating points
    # (set, not multiset: distinct assignments may price identically, and
    # only the exhaustive path keeps such duplicates)
    fe = eng.frontier(query, strategy="exhaustive")
    fl = eng.frontier(query, strategy="lattice")
    assert {_vec(c) for c in fe.configs} == {_vec(c) for c in fl.configs}
    # solver vs oracle at batch 1 under the query's constraint set
    _assert_solver_matches_oracle(cost, query.constraints())


class TestRandomizedSPStructures:
    @pytest.mark.parametrize("seed", range(8))
    def test_seeded(self, seed):
        _assert_dag_case(seed)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=20, deadline=None)
        @given(st.integers(min_value=100, max_value=10_000))
        def test_hypothesis(self, seed):
            _assert_dag_case(seed)


# ---------------------------------------------------------------------------
# chain regression + auto-dispatch
# ---------------------------------------------------------------------------

class TestChainRegression:
    def test_chain_shaped_preds_identical_to_legacy(self):
        preds = [[] if i == 0 else [i - 1] for i in range(5)]
        tree = _series([_leaf(i) for i in range(5)])
        cost, eng = _dag_space(seed=11, preds=preds, tree=tree)
        assert not eng.is_dag
        legacy = QueryEngine(cost.db, cost.resources, cost.network,
                             source="device0", input_bytes=float(1 << 13))
        for q in (Query(), Query(objective=THROUGHPUT),
                  Query(must_use=("cloud0",))):
            a, b = eng.run(q), legacy.run(q)
            assert [_vec(c) for c in a.configs] == \
                [_vec(c) for c in b.configs]
            assert a.strategy == b.strategy


class TestAutoDispatch:
    def test_strategy_recorded_and_crossover_honors_constraints(self):
        _, eng = _dag_space(seed=5)
        free = eng._search_space(Query())
        constrained = eng._search_space(
            Query(must_use=("cloud0",), exclude=("edge1",)))
        assert constrained <= free
        assert eng.run(Query()).strategy == "exhaustive"

    def test_forced_strategy_never_auto_switches(self):
        _, eng = _dag_space(seed=5)
        assert eng.frontier(Query(), strategy="lattice").strategy == "lattice"
        assert eng.frontier(Query(),
                            strategy="exhaustive").strategy == "exhaustive"

    def test_admissible_pipes_shrink_chain_search_space(self):
        cost, _ = _dag_space(seed=5)
        legacy = QueryEngine(cost.db, cost.resources, cost.network,
                             source="device0", input_bytes=float(1 << 13))
        free = legacy._search_space(Query())
        constrained = legacy._search_space(Query(must_use=("cloud0",),
                                                 exclude=("edge1",)))
        assert constrained < free
        # results are unchanged by the tighter count: both strategies agree
        q = Query(must_use=("cloud0",), exclude=("edge1",))
        got = legacy.run(q)
        assert got.configs
        for c in got.configs:
            assert "cloud0" in c.resources and "edge1" not in c.resources


# ---------------------------------------------------------------------------
# lint + executor plumbing
# ---------------------------------------------------------------------------

class TestSPDiagnostics:
    def test_non_sp_graph_warns_scn309(self):
        from repro.analysis.diagnostics import WARNING
        from repro.analysis.graph_lint import lint_graph
        diags = lint_graph(_crossed_graph())
        d309 = [d for d in diags if d.code == "SCN309"]
        assert d309 and all(d.severity == WARNING for d in d309)
        assert "b" in d309[0].message      # names the offending subgraph

    def test_branchy_graph_warns_scn310(self):
        from repro.analysis.diagnostics import WARNING
        from repro.analysis.graph_lint import lint_graph
        diags = lint_graph(_diamond_graph())
        d310 = [d for d in diags if d.code == "SCN310"]
        assert d310 and d310[0].severity == WARNING

    def test_linear_graph_emits_neither(self):
        from repro.analysis.graph_lint import lint_graph
        codes = {d.code for d in lint_graph(_linear_graph())}
        assert not codes & {"SCN309", "SCN310"}

    def test_warnings_do_not_fail_validate(self):
        _diamond_graph().validate()        # must not raise


class TestDagExecutor:
    def test_executes_branch_stages_and_matches_direct_eval(self):
        from repro.runtime.pipeline import DagPipelineExecutor
        g = _diamond_graph()
        cost, eng = _dag_space(seed=2)
        best = eng.run(Query(top_n=1)).best
        net = NetworkModel(default=Link("d", 1 / (1 << 6), float(1 << 20)))
        ex = DagPipelineExecutor(g, best, network=net, source="device0")
        x = np.arange(8, dtype=np.float32).reshape(1, 8)
        y, timings = ex.run(x, collect_timing=True)
        assert np.allclose(np.asarray(y), _run_graph(g, x))
        assert len(timings) == 5
        lat = ex.simulated_latency(timings, {r.name: 1.0
                                             for r in cost.resources})
        assert lat > 0.0

    def test_simulated_latency_overlaps_branches(self):
        """With both branches on distinct resources, the critical path
        counts max(branch), not sum(branch)."""
        from repro.runtime.pipeline import BlockTiming, DagPipelineExecutor
        g = _diamond_graph()
        ex = DagPipelineExecutor(
            g, _dag_space(seed=2)[0].evaluate_assignment(
                ("device0", "edge0", "edge1", "cloud0", "cloud0")),
            network=None, source="device0")
        timings = [BlockTiming(0, "device0", 1.0, (), 0),
                   BlockTiming(1, "edge0", 4.0, (0.0,), 0),
                   BlockTiming(2, "edge1", 4.0, (0.0,), 0),
                   BlockTiming(3, "cloud0", 1.0, (0.0, 0.0), 0),
                   BlockTiming(4, "cloud0", 1.0, (), 0)]
        lat = ex.simulated_latency(timings, {})
        assert lat == pytest.approx(1.0 + 4.0 + 1.0 + 1.0)   # not 1+4+4+1+1


class TestBranchyAdapters:
    def test_moe_adapter_emits_parallel_region(self):
        import jax
        import jax.numpy as jnp
        from repro.models import layers as L
        from repro.models.graph_adapter import moe_to_graph
        from repro.models.moe import moe_spec
        p = L.init_tree(moe_spec(16, 32, 4), jax.random.PRNGKey(0),
                        jnp.float32)
        g = moe_to_graph(p, batch=1, seq_len=4, d_model=16, n_experts=4,
                         top_k=2, n_shards=2)
        dag = fuse_block_dag(g)
        assert dag.parallel_regions and not dag.collapsed
        par = [c for c in dag.tree.children if c.kind == "parallel"]
        assert par and par[0].direct          # the residual fork-join edge

    def test_moe_dag_execution_matches_direct_eval(self):
        import jax
        import jax.numpy as jnp
        from repro.models import layers as L
        from repro.models.graph_adapter import moe_to_graph
        from repro.models.moe import moe_spec
        from repro.runtime.pipeline import DagPipelineExecutor
        p = L.init_tree(moe_spec(16, 32, 4), jax.random.PRNGKey(0),
                        jnp.float32)
        g = moe_to_graph(p, batch=1, seq_len=4, d_model=16, n_experts=4,
                         top_k=2, n_shards=2)
        dag = fuse_block_dag(g)
        res = [Resource("device0", "device", RPI4),
               Resource("edge0", "edge", EDGE_BOX_1),
               Resource("edge1", "edge", EDGE_BOX_1)]
        db = None
        from repro.core import benchmark_model
        db = benchmark_model(g, res, AnalyticProvider(), runs=1, blocks=dag)
        cost = DagCostModel(db=db, resources=res, network=NetworkModel(),
                            source="device0", input_bytes=128.0,
                            block_preds=dag.preds, tree=dag.tree)
        # split the expert shards across the two edge boxes
        assign = ["device0"] * len(dag)
        s0, s1 = dag.preds[-2][:2] if len(dag.preds[-2]) >= 2 else (1, 2)
        assign[s0], assign[s1] = "edge0", "edge1"
        cfg = cost.evaluate_assignment(tuple(assign))
        ex = DagPipelineExecutor(g, cfg, network=NetworkModel(),
                                 source="device0")
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 16),
                              jnp.bfloat16)
        want = _run_graph(g, x)
        got, _ = ex.run(x)
        assert np.allclose(np.asarray(got, dtype=np.float32),
                           np.asarray(want, dtype=np.float32),
                           rtol=1e-2, atol=1e-2)
